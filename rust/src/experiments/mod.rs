//! The paper's evaluation, experiment by experiment (§5, Appendix A).
//!
//! Every table and figure has a function here that regenerates it on the
//! discrete-event substrate; `orloj experiment <id>` (or `all`) runs them
//! and prints paper-style rows. DESIGN.md §5 maps ids to paper artifacts;
//! EXPERIMENTS.md records paper-vs-measured.

use crate::baselines::ALL_SYSTEMS;
use crate::clock::ms_to_us;
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::orderstats;
use crate::core::priority::{reference_score, ScoreContext, ScoreSchedule};
use crate::scheduler::SchedulerConfig;
use crate::serve::ElasticConfig;
use crate::sim::runner::{self, Cell, ClusterSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::azure::AzureTraceConfig;
use crate::workload::exectime::{static_tasks, table1_tasks, ExecTimeDist};
use crate::workload::trace::{ModelTraffic, TraceSpec};

/// Shared experiment knobs.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Trace duration per run (seconds of virtual time).
    pub duration_s: f64,
    /// Offered load as a fraction of batched capacity.
    pub util: f64,
    pub seed: u64,
    /// SLO multiples of P99 (paper: 1.5–5×).
    pub slos: Vec<f64>,
    /// Repetitions (paper reports std over 5 runs for Fig. 7).
    pub runs: usize,
    /// Scheduling replicas per run (the paper's per-GPU scheduler × N;
    /// offered load stays per-worker-calibrated, so N workers see N× the
    /// single-worker trace capacity).
    pub workers: usize,
    /// Router admitting arrivals to replicas (see `serve::router`).
    pub router: String,
    /// Co-served models for the `multimodel`/`elastic` grids (≥2 there;
    /// other experiments stay single-model).
    pub models: usize,
    /// Model placement spec (see `serve::Placement::parse`).
    pub placement: String,
    /// Run every grid cell under the elastic placement controller
    /// (`--elastic`; the `elastic` experiment compares both regardless).
    pub elastic: bool,
    /// Per-worker model capacity budget for elastic runs (`--capacity`).
    pub capacity: usize,
    /// Hot-model rotation period for drifting mixes, seconds (`--drift`;
    /// 0 = the experiment's default).
    pub drift_period_s: f64,
    /// Capture request-lifecycle telemetry and write
    /// `TELEMETRY_<case>.json` / `TELEMETRY_<case>.trace.json` into this
    /// directory (`--telemetry[=dir]`; empty string = `results/`).
    pub telemetry: Option<String>,
    /// Predictive admission control at this admit threshold
    /// (`--admission[=p]`; bare flag = 0.5; DESIGN.md §10). The
    /// `overload` experiment compares on/off regardless.
    pub admission: Option<f64>,
    /// Parallel event lanes for the virtual-time pump (`--shards`;
    /// DESIGN.md §11). 0 = auto (the `cluster` experiment picks the
    /// machine's parallelism; everything else stays sequential).
    pub shards: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            duration_s: 40.0,
            util: 0.9,
            seed: 42,
            slos: vec![1.5, 2.0, 3.0, 4.0, 5.0],
            runs: 1,
            workers: 1,
            router: "round_robin".into(),
            models: 1,
            placement: "all".into(),
            elastic: false,
            capacity: 2,
            drift_period_s: 0.0,
            telemetry: None,
            admission: None,
            shards: 0,
        }
    }
}

impl ExpOptions {
    /// Fast settings for CI/integration tests.
    pub fn quick() -> Self {
        ExpOptions {
            duration_s: 10.0,
            slos: vec![2.0, 4.0],
            ..Default::default()
        }
    }

    /// Cluster shape for the runner.
    fn cluster(&self) -> ClusterSpec {
        let mut spec = ClusterSpec::new(self.workers, &self.router).with_placement(&self.placement);
        if self.elastic {
            spec = spec.with_elastic(ElasticConfig {
                capacity: self.capacity.max(1),
                ..Default::default()
            });
        }
        if self.telemetry.is_some() {
            spec = spec.with_telemetry();
        }
        if let Some(t) = self.admission {
            spec = spec.with_admission(t);
        }
        if self.shards > 1 {
            spec = spec.with_shards(self.shards);
        }
        spec
    }
}

/// Build a (spec, scheduler config) pair with the batch cost model
/// calibrated to the workload's mean solo latency (see
/// [`BatchCostModel::calibrated`]) and the offered rate scaled to `util`
/// of batched capacity.
fn spec_for(
    name: &str,
    dists: Vec<ExecTimeDist>,
    opts: &ExpOptions,
    seed_off: u64,
) -> (TraceSpec, SchedulerConfig) {
    let apps = dists.len();
    // Mean solo latency across apps (uniform mix estimate).
    let mut rng = Rng::new(opts.seed ^ seed_off ^ 0xCAFE);
    let mean: f64 = dists
        .iter()
        .map(|d| d.histogram(&mut rng, 4000, 64).mean())
        .sum::<f64>()
        / apps as f64;
    let cost_model = BatchCostModel::calibrated(mean);
    let cfg = SchedulerConfig {
        cost_model,
        ..Default::default()
    };
    let mut spec = TraceSpec {
        name: name.to_string(),
        dists,
        arrivals: AzureTraceConfig {
            apps,
            rate_per_s: 0.0,
            duration_s: opts.duration_s,
            ..Default::default()
        },
        seed: opts.seed ^ seed_off,
        models: Vec::new(),
    };
    spec.scale_rate_to_load(cost_model, opts.util, 8);
    (spec, cfg)
}

/// One app per lognormal mode (the paper's reading of modality: "increase
/// the number of modalities ... to simulate the effect of multiple
/// applications").
fn modal_apps(k: usize, sigma: f64, weights: Option<Vec<f64>>) -> Vec<ExecTimeDist> {
    let w = weights.unwrap_or_else(|| vec![1.0; k]);
    (0..k)
        .map(|i| {
            let frac = if k == 1 { 0.5 } else { i as f64 / (k - 1) as f64 };
            let center = 10.0 * (100.0f64 / 10.0).powf(frac);
            let name = format!("app{i}");
            // One peak per app; per-app weight folds into arrival shares
            // via duplication of the dist list (cheap approximation kept
            // deterministic by the arrival process itself).
            let _ = &w;
            ExecTimeDist::multimodal(&name, 1, center, center, sigma, None)
        })
        .collect()
}

/// Run the 5-system grid for one workload; returns cells averaged over
/// `opts.runs` repetitions.
fn grid(name: &str, dists: Vec<ExecTimeDist>, opts: &ExpOptions, seed_off: u64) -> Vec<Cell> {
    let mut acc: Vec<Cell> = Vec::new();
    for run in 0..opts.runs.max(1) {
        let (spec, cfg) = spec_for(name, dists.clone(), opts, seed_off ^ (run as u64) << 32);
        let cells = runner::run_grid(
            &ALL_SYSTEMS,
            &spec,
            &opts.slos,
            &cfg,
            spec.seed,
            &opts.cluster(),
        );
        if acc.is_empty() {
            acc = cells;
        } else {
            // Average finish-rate-bearing fields by merging reports is
            // overkill; keep the first run's latency detail and average the
            // headline counts.
            for (a, c) in acc.iter_mut().zip(cells) {
                a.report.finished += c.report.finished;
                a.report.total += c.report.total;
                a.report.late += c.report.late;
                a.report.timed_out += c.report.timed_out;
                a.report.aborted += c.report.aborted;
                for (m, r) in c.report.per_model {
                    if let Some(ar) = a.report.per_model.get_mut(&m) {
                        ar.finished += r.finished;
                        ar.total += r.total;
                    } else {
                        a.report.per_model.insert(m, r);
                    }
                }
            }
        }
    }
    acc
}

fn print_grid(title: &str, cells: &[Cell], opts: &ExpOptions) {
    print!("{}", runner::render_table(title, cells, &ALL_SYSTEMS));
    if cells.iter().any(|c| c.workers > 1) {
        print!(
            "{}",
            runner::render_worker_util("per-worker utilization", cells)
        );
    }
    if cells.iter().any(|c| c.report.per_model.len() > 1) {
        print!(
            "{}",
            runner::render_model_rates("per-model finish rates", cells)
        );
    }
    if cells.iter().any(|c| c.placement.actions() > 0) {
        print!(
            "{}",
            runner::render_placement_actions("placement actions", cells)
        );
    }
    if cells.iter().any(|c| c.telemetry.is_some()) {
        print!(
            "{}",
            runner::render_calibration("estimator calibration (predicted vs realized, ms)", cells)
        );
    }
    if let Some(dir) = &opts.telemetry {
        export_telemetry(dir, title, cells);
    }
}

/// Write the telemetry exports for one grid case: the windowed time
/// series + calibration stream for every telemetry-bearing cell
/// (`TELEMETRY_<case>.json`) and a Perfetto-loadable Chrome trace for a
/// representative cell (`TELEMETRY_<case>.trace.json`; the first `orloj`
/// cell, else the first cell with telemetry). No-op when no cell
/// recorded telemetry.
pub fn export_telemetry(dir: &str, label: &str, cells: &[Cell]) {
    if cells.iter().all(|c| c.telemetry.is_none()) {
        return;
    }
    let dir = if dir.is_empty() { "results" } else { dir };
    // Create the directory on demand and surface I/O failures instead of
    // silently dropping the export: a user who asked for `--telemetry=dir`
    // should hear about an unwritable dir, not find it empty later.
    if let Err(e) = std::fs::create_dir_all(dir) {
        eprintln!("telemetry export: cannot create {dir}: {e}");
        return;
    }
    let slug: String = label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    let series = Json::arr(cells.iter().filter_map(|c| {
        let rec = c.telemetry.as_ref()?;
        Some(Json::obj(vec![
            ("system", Json::str(&c.system)),
            ("slo", Json::num(c.slo_multiple)),
            ("series", rec.time_series()),
        ]))
    }));
    let path = std::path::Path::new(dir).join(format!("TELEMETRY_{slug}.json"));
    if let Err(e) = std::fs::write(&path, series.to_pretty()) {
        eprintln!("telemetry export: cannot write {}: {e}", path.display());
        return;
    }
    let rep = cells
        .iter()
        .find(|c| c.system == "orloj" && c.telemetry.is_some())
        .or_else(|| cells.iter().find(|c| c.telemetry.is_some()));
    if let Some(rec) = rep.and_then(|c| c.telemetry.as_ref()) {
        let tpath = std::path::Path::new(dir).join(format!("TELEMETRY_{slug}.trace.json"));
        if let Err(e) = std::fs::write(&tpath, rec.chrome_trace().to_string()) {
            eprintln!("telemetry export: cannot write {}: {e}", tpath.display());
            return;
        }
        println!(
            "(telemetry written to {} and {})",
            path.display(),
            tpath.display()
        );
    } else {
        println!("(telemetry written to {})", path.display());
    }
}

fn cells_to_json(case: &str, cells: &[Cell]) -> Json {
    Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("case", Json::str(case)),
            ("system", Json::str(&c.system)),
            ("slo", Json::num(c.slo_multiple)),
            ("finish_rate", Json::num(c.report.finish_rate())),
            ("total", Json::num(c.report.total as f64)),
            ("aborted", Json::num(c.report.aborted as f64)),
            ("timed_out", Json::num(c.report.timed_out as f64)),
            ("utilization", Json::num(c.utilization)),
            ("workers", Json::num(c.workers as f64)),
            ("admitted", Json::num(c.admission.admitted as f64)),
            ("downgraded", Json::num(c.admission.downgraded as f64)),
            (
                "early_rejected",
                Json::num(c.admission.early_rejected as f64),
            ),
            (
                "best_effort_served",
                Json::num(c.admission.best_effort_served as f64),
            ),
            ("load_actions", Json::num(c.placement.loads as f64)),
            ("unload_actions", Json::num(c.placement.unloads as f64)),
            ("rerouted", Json::num(c.placement.rerouted as f64)),
            (
                "react_s",
                Json::num(c.placement.first_action_at as f64 / 1e6),
            ),
            (
                "converge_s",
                Json::num(c.placement.last_action_at as f64 / 1e6),
            ),
            (
                "per_worker_utilization",
                Json::arr(
                    c.report
                        .per_worker
                        .iter()
                        .map(|w| Json::num(w.utilization)),
                ),
            ),
            (
                "per_worker_batches",
                Json::arr(
                    c.report
                        .per_worker
                        .iter()
                        .map(|w| Json::num(w.batches as f64)),
                ),
            ),
            (
                "per_model",
                Json::arr(c.report.per_model.iter().map(|(m, r)| {
                    Json::obj(vec![
                        ("model", Json::num(*m as f64)),
                        ("finish_rate", Json::num(r.finish_rate())),
                        ("finished", Json::num(r.finished as f64)),
                        ("total", Json::num(r.total as f64)),
                        ("lat_p50", Json::num(r.latency.p50)),
                        ("lat_p99", Json::num(r.latency.p99)),
                    ])
                })),
            ),
        ])
    }))
}

/// Persist experiment output under results/.
pub fn save_results(id: &str, rows: Json) {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join(format!("{id}.json"));
    std::fs::write(&path, rows.to_pretty()).ok();
    println!("(results written to {})", path.display());
}

// ---------------------------------------------------------------------
// Fig. 2 — execution-time histograms of dynamic models
// ---------------------------------------------------------------------

pub fn fig2(_opts: &ExpOptions) -> Json {
    println!("### Fig. 2 — request execution time histograms (Table 1 presets)");
    let mut rng = Rng::new(2);
    let mut out = Vec::new();
    for task in table1_tasks().iter().chain(static_tasks().iter()) {
        let h = task.dist.histogram(&mut rng, 40_000, 40);
        let spark: String = h
            .masses()
            .iter()
            .map(|&m| {
                let lvl = (m * 40.0 * 8.0).min(7.0) as usize;
                [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇'][lvl]
            })
            .collect();
        println!(
            "{:>20}  [{:8.2}..{:8.2} ms]  |{spark}|  mean={:.1} p99={:.1}",
            task.id,
            h.lo(),
            h.hi(),
            h.mean(),
            h.p99()
        );
        out.push(Json::obj(vec![
            ("task", Json::str(task.id)),
            ("mean_ms", Json::num(h.mean())),
            ("p99_ms", Json::num(h.p99())),
            ("lo", Json::num(h.lo())),
            ("hi", Json::num(h.hi())),
            (
                "masses",
                Json::arr(h.masses().iter().map(|&m| Json::num(m))),
            ),
        ]));
    }
    Json::arr(out)
}

// ---------------------------------------------------------------------
// Fig. 3 — existing systems on three distributions
// ---------------------------------------------------------------------

pub fn fig3(opts: &ExpOptions) -> Json {
    println!("### Fig. 3 — existing solutions vs distribution shape\n");
    let cases: Vec<(&str, Vec<ExecTimeDist>)> = vec![
        ("uniform", modal_apps(6, 2.0, None)),
        ("bimodal-equal", modal_apps(2, 1.0, None)),
        (
            "bimodal-inequal",
            vec![
                ExecTimeDist::multimodal("bi", 2, 10.0, 100.0, 1.0, Some(vec![0.8, 0.2])),
            ],
        ),
    ];
    let mut all = Vec::new();
    for (case, dists) in cases {
        let cells = grid(case, dists, opts, 0x31);
        print_grid(case, &cells, opts);
        println!();
        all.push(cells_to_json(case, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Fig. 6 — toy example: batch distribution + p(t) curves
// ---------------------------------------------------------------------

pub fn fig6(_opts: &ExpOptions) -> Json {
    println!("### Fig. 6 — toy example");
    // Two request types with equal means: concentrated vs early-or-late.
    let d1 = Histogram::from_weights(4.0, 1.0, &[0.05, 0.9, 0.05]);
    let d2 = Histogram::from_weights(1.0, 1.0, &[0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.5]);
    let batch = orderstats::max_inid_direct(&[&d1, &d2], 32);
    println!(
        "(a) means: d1={:.2} d2={:.2}   (b) batch(k=2): mean={:.2} (right-skewed)",
        d1.mean(),
        d2.mean(),
        batch.mean()
    );
    let ctx = ScoreContext::new(1e-4);
    let mk = |deadline_ms: f64| ScoreSchedule::build(&ctx, ms_to_us(deadline_ms), 1.0, &batch);
    let (r1, r2, r3) = (mk(40.0), mk(70.0), mk(100.0));
    println!("(c) p(t) for r1 (D=40), r2 (D=70), r3 (D=100):");
    println!("{:>6} {:>12} {:>12} {:>12}", "t(ms)", "p(r1)", "p(r2)", "p(r3)");
    let mut series = Vec::new();
    let mut t = 0.0;
    while t <= 110.0 {
        let (p1, p2, p3) = (
            r1.score_at(1e-4, t),
            r2.score_at(1e-4, t),
            r3.score_at(1e-4, t),
        );
        println!("{t:>6.0} {p1:>12.4} {p2:>12.4} {p3:>12.4}");
        series.push(Json::arr(vec![
            Json::num(t),
            Json::num(p1),
            Json::num(p2),
            Json::num(p3),
        ]));
        t += 10.0;
    }
    // Sanity: matches the slow reference.
    let slow = reference_score(1e-4, 40.0, 1.0, &batch, 10.0);
    assert!((r1.score_at(1e-4, 10.0) - slow).abs() < 1e-9 * (1.0 + slow.abs()));
    Json::obj(vec![
        ("batch_mean", Json::num(batch.mean())),
        ("series", Json::arr(series)),
    ])
}

// ---------------------------------------------------------------------
// Table 2 / Figs 9–10 — bimodal σ sweep + unequal peaks
// ---------------------------------------------------------------------

pub fn table2(opts: &ExpOptions) -> Json {
    println!("### Table 2 / Figs 9-10 — bimodal distribution parameters\n");
    let cases: Vec<(&str, Vec<ExecTimeDist>)> = vec![
        ("std-0.5", modal_apps(2, 0.5, None)),
        ("std-1", modal_apps(2, 1.0, None)),
        ("std-2", modal_apps(2, 2.0, None)),
        (
            "std-2/0.5", // more short requests
            vec![ExecTimeDist::multimodal("b", 2, 10.0, 100.0, 1.0, Some(vec![0.8, 0.2]))],
        ),
        (
            "std-0.5/2", // more long requests
            vec![ExecTimeDist::multimodal("b", 2, 10.0, 100.0, 1.0, Some(vec![0.2, 0.8]))],
        ),
    ];
    let mut all = Vec::new();
    for (case, dists) in cases {
        let cells = grid(case, dists, opts, 0x92);
        print_grid(case, &cells, opts);
        println!();
        all.push(cells_to_json(case, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Table 3 / Fig. 8 — modality sweep (1..8 modal)
// ---------------------------------------------------------------------

pub fn table3(opts: &ExpOptions) -> Json {
    println!("### Table 3 / Fig. 8 — modality sweep\n");
    let names = [
        "one-modal",
        "two-modal",
        "three-modal",
        "four-modal",
        "five-modal",
        "six-modal",
        "seven-modal",
        "eight-modal",
    ];
    let mut all = Vec::new();
    for (i, case) in names.iter().enumerate() {
        let k = i + 1;
        let cells = grid(case, modal_apps(k, 1.0, None), opts, 0x30 + k as u64);
        print_grid(case, &cells, opts);
        println!();
        all.push(cells_to_json(case, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Table 4 / Fig. 11 — static models
// ---------------------------------------------------------------------

pub fn table4(opts: &ExpOptions) -> Json {
    println!("### Table 4 / Fig. 11 — static models (no exec-time variance)\n");
    let mut all = Vec::new();
    for task in static_tasks() {
        let cells = grid(task.id, vec![task.dist.clone()], opts, 0x40);
        print_grid(task.id, &cells, opts);
        println!();
        all.push(cells_to_json(task.id, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Table 5 / Fig. 7 — real-world tasks
// ---------------------------------------------------------------------

pub fn table5(opts: &ExpOptions) -> Json {
    println!("### Table 5 / Fig. 7 — real-world tasks (Table 1 presets)\n");
    let mut all = Vec::new();
    for task in table1_tasks() {
        let cells = grid(task.id, vec![task.dist.clone()], opts, 0x50);
        print_grid(task.id, &cells, opts);
        println!();
        all.push(cells_to_json(task.id, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Fig. 13 — sensitivity to b
// ---------------------------------------------------------------------

pub fn fig13(opts: &ExpOptions) -> Json {
    println!("### Fig. 13 — sensitivity to the anticipated-delay parameter b\n");
    let dist = ExecTimeDist::multimodal("three-modal", 3, 10.0, 100.0, 1.0, None);
    let bs = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1];
    println!(
        "{:>8} {}",
        "b",
        opts.slos
            .iter()
            .map(|s| format!("{:>10}", format!("slo{x:.1}", x = s)))
            .collect::<String>()
    );
    let mut rows = Vec::new();
    for &b in &bs {
        let (spec, mut cfg) = spec_for("fig13", modal_apps(3, 1.0, None), opts, 0x13);
        let _ = &dist;
        cfg.b = b;
        let cells = runner::run_grid(
            &["orloj"],
            &spec,
            &opts.slos,
            &cfg,
            spec.seed,
            &opts.cluster(),
        );
        print!("{b:>8.0e}");
        for c in &cells {
            print!("{:>10.2}", c.report.finish_rate());
        }
        println!();
        for c in &cells {
            rows.push(Json::obj(vec![
                ("b", Json::num(b)),
                ("slo", Json::num(c.slo_multiple)),
                ("finish_rate", Json::num(c.report.finish_rate())),
            ]));
        }
    }
    Json::arr(rows)
}

// ---------------------------------------------------------------------
// Fig. 14 — overheads: minimum execution time scaling
// ---------------------------------------------------------------------

pub fn fig14(opts: &ExpOptions) -> Json {
    println!("### Fig. 14 — scheduling overheads vs minimum execution time\n");
    let base = ExecTimeDist::multimodal("three-modal", 3, 10.0, 100.0, 1.0, None);
    let mut rng = Rng::new(14);
    let base_p99 = base.p99(&mut rng, 50_000);
    // Scale so the P99 sweeps 200 → 2 ms (paper's x-axis).
    let targets = [200.0, 100.0, 50.0, 20.0, 10.0, 5.0, 2.0];
    println!(
        "{:>10} {}",
        "p99(ms)",
        opts.slos
            .iter()
            .map(|s| format!("{:>10}", format!("slo{x:.1}", x = s)))
            .collect::<String>()
    );
    let mut rows = Vec::new();
    for &p99 in &targets {
        let scale = p99 / base_p99;
        let dists: Vec<ExecTimeDist> =
            modal_apps(3, 1.0, None).iter().map(|d| d.scaled(scale)).collect();
        let (spec, cfg) = spec_for("fig14", dists, opts, 0x14);
        let cells = runner::run_grid(
            &["orloj"],
            &spec,
            &opts.slos,
            &cfg,
            spec.seed,
            &opts.cluster(),
        );
        print!("{p99:>10.1}");
        for c in &cells {
            print!("{:>10.2}", c.report.finish_rate());
        }
        println!();
        for c in &cells {
            rows.push(Json::obj(vec![
                ("p99_ms", Json::num(p99)),
                ("slo", Json::num(c.slo_multiple)),
                ("finish_rate", Json::num(c.report.finish_rate())),
            ]));
        }
    }
    Json::arr(rows)
}

// ---------------------------------------------------------------------
// Multi-model (beyond the paper): skewed model mixes on a shared fleet
// ---------------------------------------------------------------------

/// Build the hot-plus-cold model mix: model 0 is a fast low-variance
/// model; models 1.. are slower and increasingly multimodal.
fn multimodel_models(m: usize, shares: &[f64]) -> Vec<ModelTraffic> {
    (0..m)
        .map(|j| {
            let dists = if j == 0 {
                vec![ExecTimeDist::lognormal_mean_p99("hot-fast", 10.0, 18.0)]
            } else {
                vec![ExecTimeDist::multimodal(
                    &format!("cold{j}-slow"),
                    2,
                    (15.0 * j as f64).min(100.0),
                    120.0,
                    1.0,
                    None,
                )]
            };
            ModelTraffic::new(j as u32, shares[j], dists)
        })
        .collect()
}

pub fn multimodel(opts: &ExpOptions) -> Json {
    let m = opts.models.max(2);
    println!(
        "### multimodel — skewed traffic mixes over {m} co-served models \
         ({} workers, placement '{}')\n",
        opts.workers, opts.placement
    );
    let spread = |hot: f64| -> Vec<f64> {
        let mut s = vec![(1.0 - hot) / (m - 1) as f64; m];
        s[0] = hot;
        s
    };
    let mixes: Vec<(String, Vec<f64>)> = vec![
        ("even-mix".into(), vec![1.0 / m as f64; m]),
        ("hot-80".into(), spread(0.8)),
        ("hot-95".into(), spread(0.95)),
    ];
    let mut all = Vec::new();
    for (case, shares) in mixes {
        let models = multimodel_models(m, &shares);
        // Calibrate the shared cost model to the share-weighted mean solo
        // latency across models (per-model curves come from the spec via
        // the runner).
        let mut rng = Rng::new(opts.seed ^ 0x3D);
        let mean: f64 = models
            .iter()
            .map(|mt| {
                mt.share
                    * mt.dists
                        .iter()
                        .map(|d| d.histogram(&mut rng, 4000, 64).mean())
                        .sum::<f64>()
                    / mt.dists.len() as f64
            })
            .sum::<f64>()
            / shares.iter().sum::<f64>();
        let cost_model = BatchCostModel::calibrated(mean);
        let cfg = SchedulerConfig {
            cost_model,
            ..Default::default()
        };
        let mut spec = TraceSpec {
            name: case.clone(),
            dists: Vec::new(),
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0,
                duration_s: opts.duration_s,
                ..Default::default()
            },
            seed: opts.seed ^ 0x3D,
            models,
        };
        spec.scale_rate_to_load(cost_model, opts.util, 8);
        let cells = runner::run_grid(
            &ALL_SYSTEMS,
            &spec,
            &opts.slos,
            &cfg,
            spec.seed,
            &opts.cluster(),
        );
        print_grid(&case, &cells, opts);
        println!();
        all.push(cells_to_json(&case, &cells));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Elastic placement (beyond the paper): static vs elastic under drift
// ---------------------------------------------------------------------

/// Static-vs-elastic placement on drifting traffic mixes: the hot model
/// rotates every `--drift` seconds while the cluster has only
/// `--capacity` model slots per worker, so a fixed placement is wrong for
/// most of the run. Reports finish rate per mode, the elastic
/// controller's load/unload action counts, and its time-to-converge
/// (last placement action) for all five systems at two skew levels.
pub fn elastic(opts: &ExpOptions) -> Json {
    let m = opts.models.max(3);
    let workers = opts.workers.max(4);
    let period = if opts.drift_period_s > 0.0 {
        opts.drift_period_s
    } else {
        8.0
    };
    // Feasibility floor: every model must fit the cluster even statically.
    let capacity = opts.capacity.max(1).max(m.div_ceil(workers));
    let slo = *opts.slos.get(opts.slos.len() / 2).unwrap_or(&3.0);
    println!(
        "### elastic — static vs elastic placement under a drifting mix \
         ({workers} workers × {m} models, capacity {capacity}, rotation {period}s, slo {slo}x)\n"
    );
    let static_placements = ["partition", "skewed"];
    let mut all = Vec::new();
    for hot in [0.70, 0.90] {
        let case = format!("drift-hot{:.0}", hot * 100.0);
        let shares = vec![1.0 / m as f64; m];
        let models = multimodel_models(m, &shares);
        // Shared cost model calibrated to the (time-averaged) even mix.
        let mut rng = Rng::new(opts.seed ^ 0xE1A5);
        let mean: f64 = models
            .iter()
            .map(|mt| {
                mt.dists
                    .iter()
                    .map(|d| d.histogram(&mut rng, 4000, 64).mean())
                    .sum::<f64>()
                    / mt.dists.len() as f64
            })
            .sum::<f64>()
            / m as f64;
        let cost_model = BatchCostModel::calibrated(mean);
        let cfg = SchedulerConfig {
            cost_model,
            ..Default::default()
        };
        let mut spec = TraceSpec {
            name: case.clone(),
            dists: Vec::new(),
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0,
                duration_s: opts.duration_s,
                ..Default::default()
            },
            seed: opts.seed ^ 0xE1A5,
            models,
        };
        spec.scale_rate_to_load(cost_model, opts.util * workers as f64, 8);
        let spec = spec.drift_rotating(period, hot);
        let trace = spec.generate();
        let ecfg = ElasticConfig {
            capacity,
            ..Default::default()
        };
        println!(
            "{:>10} {:>12} {:>12} {:>12} {:>7} {:>9} {:>9} {:>8}  [{case}]",
            "system", "partition", "skewed", "elastic", "loads", "unloads", "react(s)", "last(s)"
        );
        let mut rows = Vec::new();
        let mut ecells = Vec::new();
        for system in ALL_SYSTEMS {
            let mut static_rates = Vec::new();
            for ps in static_placements {
                let cell = runner::run_one(
                    system,
                    &spec,
                    &trace,
                    slo,
                    &cfg,
                    spec.seed,
                    &ClusterSpec::new(workers, &opts.router).with_placement(ps),
                );
                static_rates.push(cell.report.finish_rate());
                rows.push(Json::obj(vec![
                    ("case", Json::str(&case)),
                    ("system", Json::str(system)),
                    ("mode", Json::str(&format!("static-{ps}"))),
                    ("slo", Json::num(slo)),
                    ("finish_rate", Json::num(cell.report.finish_rate())),
                    ("load_actions", Json::num(0.0)),
                    ("unload_actions", Json::num(0.0)),
                    ("converge_s", Json::num(0.0)),
                ]));
            }
            let mut ecspec = ClusterSpec::new(workers, &opts.router)
                .with_placement("partition")
                .with_elastic(ecfg.clone());
            if opts.telemetry.is_some() {
                ecspec = ecspec.with_telemetry();
            }
            let ecell = runner::run_one(system, &spec, &trace, slo, &cfg, spec.seed, &ecspec);
            let erate = ecell.report.finish_rate();
            println!(
                "{:>10} {:>12.3} {:>12.3} {:>12.3} {:>7} {:>9} {:>9.1} {:>8.1}",
                system,
                static_rates[0],
                static_rates[1],
                erate,
                ecell.placement.loads,
                ecell.placement.unloads,
                ecell.placement.first_action_at as f64 / 1e6,
                ecell.placement.last_action_at as f64 / 1e6,
            );
            rows.push(Json::obj(vec![
                ("case", Json::str(&case)),
                ("system", Json::str(system)),
                ("mode", Json::str("elastic")),
                ("slo", Json::num(slo)),
                ("finish_rate", Json::num(erate)),
                ("load_actions", Json::num(ecell.placement.loads as f64)),
                (
                    "unload_actions",
                    Json::num(ecell.placement.unloads as f64),
                ),
                ("rerouted", Json::num(ecell.placement.rerouted as f64)),
                (
                    "react_s",
                    Json::num(ecell.placement.first_action_at as f64 / 1e6),
                ),
                (
                    "converge_s",
                    Json::num(ecell.placement.last_action_at as f64 / 1e6),
                ),
                (
                    "best_static",
                    Json::num(static_rates.iter().cloned().fold(f64::MIN, f64::max)),
                ),
            ]));
            ecells.push(ecell);
        }
        if ecells.iter().any(|c| c.telemetry.is_some()) {
            print!(
                "{}",
                runner::render_calibration("estimator calibration (elastic mode)", &ecells)
            );
        }
        if let Some(dir) = &opts.telemetry {
            export_telemetry(dir, &case, &ecells);
        }
        println!();
        all.push(Json::arr(rows));
    }
    Json::arr(all)
}

// ---------------------------------------------------------------------
// Ablation (beyond the paper): EDF baseline + feasibility quantile
// ---------------------------------------------------------------------

pub fn ablation(opts: &ExpOptions) -> Json {
    println!("### Ablation — distribution-aware score vs plain EDF; feasibility quantile\n");
    let (spec, cfg) = spec_for("ablation", modal_apps(3, 1.0, None), opts, 0xAB);
    let cells = runner::run_grid(
        &["edf", "orloj"],
        &spec,
        &opts.slos,
        &cfg,
        spec.seed,
        &opts.cluster(),
    );
    print!("{}", runner::render_table("orloj vs edf", &cells, &["edf", "orloj"]));
    println!();
    let mut rows = vec![cells_to_json("edf-vs-orloj", &cells)];
    println!("feasibility quantile sweep (orloj, slo=3x):");
    for q in [0.25, 0.5, 0.75, 0.95] {
        let mut c = cfg.clone();
        c.feasibility_quantile = q;
        let cells = runner::run_grid(&["orloj"], &spec, &[3.0], &c, spec.seed, &opts.cluster());
        println!("  q={q:>5}: finish_rate={:.3}", cells[0].report.finish_rate());
        rows.push(cells_to_json(&format!("quantile-{q}"), &cells));
    }
    Json::arr(rows)
}

// ---------------------------------------------------------------------
// Overload (beyond the paper): predictive admission vs shed-at-formation
// ---------------------------------------------------------------------

/// Early-reject precision: of the requests the gated run rejected at
/// arrival, the fraction the ungated baseline also failed to finish on
/// the identical trace (a shadow comparison over shared request ids).
/// `None` when either run lacks telemetry or nothing was rejected.
fn reject_precision(base: &Cell, adm: &Cell) -> Option<f64> {
    use crate::core::request::Outcome;
    use crate::telemetry::EventKind;
    use std::collections::HashSet;
    let arec = adm.telemetry.as_ref()?;
    let brec = base.telemetry.as_ref()?;
    let rejected: HashSet<u64> = arec
        .events()
        .filter_map(|e| match e.kind {
            EventKind::EarlyReject { req, .. } => Some(req.0),
            _ => None,
        })
        .collect();
    if rejected.is_empty() {
        return None;
    }
    let doomed = brec
        .events()
        .filter(|e| match e.kind {
            EventKind::Terminal { req, outcome, .. } => {
                rejected.contains(&req.0) && outcome != Outcome::Finished
            }
            _ => false,
        })
        .count();
    Some(doomed as f64 / rejected.len() as f64)
}

/// Sweep offered load 1–3× of batched capacity and compare every system
/// with predictive admission control (DESIGN.md §10) against its own
/// shed-at-formation baseline on the same trace. Reports goodput
/// (SLO-lane finishes per second of virtual time), wasted work
/// (execution milliseconds burnt on completions that missed their
/// deadline anyway), early-reject precision (see [`reject_precision`]),
/// and the per-app admitted-share spread from the deficit-counter
/// fairness guard (two apps: fast + slow).
pub fn overload(opts: &ExpOptions) -> Json {
    let threshold = opts.admission.unwrap_or(0.5);
    let slo = *opts.slos.get(opts.slos.len() / 2).unwrap_or(&2.0);
    // Quick runs (CI smoke) sweep three loads; full runs five.
    let loads: &[f64] = if opts.duration_s <= 10.0 {
        &[1.0, 2.0, 3.0]
    } else {
        &[1.0, 1.5, 2.0, 2.5, 3.0]
    };
    println!(
        "### overload — predictive admission vs shed-at-formation \
         (slo {slo}x, threshold {threshold}, 2 apps)\n"
    );
    let dur_s = opts.duration_s.max(1e-9);
    let mut all = Vec::new();
    for &load in loads {
        let case = format!("overload-x{load:.1}");
        let mut lopts = opts.clone();
        // `util` is calibrated as a fraction of batched capacity; the
        // sweep pushes the same workload past saturation.
        lopts.util = opts.util.min(1.0) * load;
        let (spec, cfg) = spec_for(&case, modal_apps(2, 1.0, None), &lopts, 0x0D);
        let trace = spec.generate();
        println!(
            "{:>10} {:>6} {:>6} {:>8} {:>8} {:>9} {:>9} {:>6} {:>6} {:>6} {:>6} {:>7}  [{case}]",
            "system",
            "shed%",
            "adm%",
            "shedgp",
            "admgp",
            "shedwst",
            "admwst",
            "A",
            "D",
            "R",
            "prec",
            "spread"
        );
        let mut rows = Vec::new();
        let mut adm_cells = Vec::new();
        for system in ALL_SYSTEMS {
            // Telemetry on both runs: the precision shadow comparison
            // needs per-request outcomes from the baseline and reject ids
            // from the gated run over the identical trace.
            let base_cluster = ClusterSpec::new(opts.workers, &opts.router)
                .with_placement(&opts.placement)
                .with_telemetry();
            let adm_cluster = base_cluster.clone().with_admission(threshold);
            let base =
                runner::run_one(system, &spec, &trace, slo, &cfg, spec.seed, &base_cluster);
            let adm = runner::run_one(system, &spec, &trace, slo, &cfg, spec.seed, &adm_cluster);
            let precision = reject_precision(&base, &adm);
            let spread = adm.admission.admit_share_spread().map(|(lo, hi)| hi - lo);
            let gp = |c: &Cell| c.report.finished as f64 / dur_s;
            println!(
                "{:>10} {:>6.2} {:>6.2} {:>8.1} {:>8.1} {:>9.0} {:>9.0} {:>6} {:>6} {:>6} {:>6} {:>7}",
                system,
                base.report.finish_rate(),
                adm.report.finish_rate(),
                gp(&base),
                gp(&adm),
                base.report.wasted_ms,
                adm.report.wasted_ms,
                adm.admission.admitted,
                adm.admission.downgraded,
                adm.admission.early_rejected,
                precision.map_or("-".into(), |p| format!("{p:.2}")),
                spread.map_or("-".into(), |s| format!("{s:.2}")),
            );
            rows.push(Json::obj(vec![
                ("case", Json::str(&case)),
                ("load", Json::num(load)),
                ("system", Json::str(system)),
                ("slo", Json::num(slo)),
                ("shed_finish_rate", Json::num(base.report.finish_rate())),
                ("adm_finish_rate", Json::num(adm.report.finish_rate())),
                ("shed_goodput", Json::num(gp(&base))),
                ("adm_goodput", Json::num(gp(&adm))),
                ("shed_wasted_ms", Json::num(base.report.wasted_ms)),
                ("adm_wasted_ms", Json::num(adm.report.wasted_ms)),
                ("admitted", Json::num(adm.admission.admitted as f64)),
                ("downgraded", Json::num(adm.admission.downgraded as f64)),
                (
                    "early_rejected",
                    Json::num(adm.admission.early_rejected as f64),
                ),
                (
                    "best_effort_served",
                    Json::num(adm.admission.best_effort_served as f64),
                ),
                ("reject_precision", precision.map_or(Json::Null, Json::num)),
                ("fairness_spread", spread.map_or(Json::Null, Json::num)),
            ]));
            adm_cells.push(adm);
        }
        if let Some(dir) = &opts.telemetry {
            export_telemetry(dir, &case, &adm_cells);
        }
        println!();
        all.push(Json::arr(rows));
    }
    Json::arr(all)
}

/// `experiment cluster` (DESIGN.md §11): simulator-throughput scale
/// sweep. Replays a bursty azure arrival trace through every cell of a
/// (workers × models) grid twice — once on the sequential virtual-time
/// pump, once on the sharded pump — and reports wall-clock events/s,
/// discrete-event step counts and the process peak RSS. A machine-
/// readable copy lands in `BENCH_serve.json` (bench `cluster_scale`;
/// `ORLOJ_BENCH_OUT` redirects the directory). The timed section is the
/// replay only: trace generation, scheduler build and profile seeding
/// happen outside the clock, identically for both pumps.
fn cluster_scale(opts: &ExpOptions) -> Json {
    use crate::clock::VirtualClock;
    use crate::serve::{replay, router, Cluster, Placement, ServingLoop};
    use crate::sim::engine::EngineResult;
    use crate::sim::worker::SimWorker;
    use crate::util::benchmark;
    use std::time::Instant;

    let quick = benchmark::quick_mode() || opts.duration_s <= 10.0;
    let (worker_grid, model_grid, duration_s): (&[usize], &[usize], f64) = if quick {
        (&[4, 16], &[10, 50], 2.0)
    } else {
        (
            &[4, 16, 64, 256],
            &[10, 100, 1000],
            opts.duration_s.clamp(4.0, 16.0),
        )
    };
    let system = "orloj";
    let slo_multiple = 4.0;
    let auto_shards = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    println!("### cluster scale sweep ({system}, round_robin, placement=all)");
    println!(
        "{:>8} {:>7} {:>9} {:>7} {:>12} {:>12} {:>8} {:>9}",
        "workers", "models", "requests", "shards", "seq_ev/s", "par_ev/s", "speedup", "rss_mb"
    );
    let mut rows = Vec::new();
    for &workers in worker_grid {
        for &models in model_grid {
            let cost_model = BatchCostModel::calibrated(10.0);
            let mut cfg = SchedulerConfig {
                cost_model,
                ..Default::default()
            };
            let mut spec = TraceSpec {
                name: format!("cluster-w{workers}-m{models}"),
                dists: Vec::new(),
                arrivals: AzureTraceConfig {
                    apps: 1,
                    rate_per_s: 0.0,
                    duration_s,
                    burst_sigma: 0.6,
                    ..Default::default()
                },
                seed: opts.seed ^ ((workers as u64) << 20) ^ models as u64,
                models: (0..models)
                    .map(|m| {
                        ModelTraffic::new(
                            m as u32,
                            1.0 / models as f64,
                            vec![ExecTimeDist::constant("unit", 10.0)],
                        )
                    })
                    .collect(),
            };
            // Offered load is calibrated per worker, then multiplied out
            // to the cluster: N workers see N× the single-worker trace.
            spec.scale_rate_to_load(cost_model, opts.util.min(0.7), 8);
            spec.arrivals.rate_per_s *= workers as f64;
            cfg.model_costs = spec.model_cost_models();
            let trace = spec.generate();
            let n_requests = trace.events.len();

            let build = || {
                let placement = Placement::parse_checked("all", workers, models)
                    .expect("'all' placement always parses");
                let mut replicas = Cluster::build_placed(system, &cfg, spec.seed, placement)
                    .expect("known system");
                for (model, app, hist) in spec.seed_histograms(cfg.bins) {
                    replicas.seed_app_profile(model, app, &hist, 1000);
                }
                let sim_workers: Vec<SimWorker> = (0..workers)
                    .map(|w| {
                        SimWorker::new(
                            cfg.cost_model,
                            0.0,
                            spec.seed ^ 0x5151 ^ ((w as u64) << 16),
                        )
                        .with_model_costs(cfg.model_costs.clone())
                    })
                    .collect();
                let core = ServingLoop::new(
                    VirtualClock::new(),
                    replicas,
                    router::by_name("round_robin").expect("registry has round_robin"),
                );
                (core, sim_workers)
            };
            let timed = |shards: usize| {
                let (core, sim_workers) = build();
                let requests = trace.requests(slo_multiple);
                let t0 = Instant::now();
                let res = replay::run_cluster_sharded(core, sim_workers, requests, shards);
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                assert_eq!(res.completions.len(), n_requests, "conservation");
                (res, wall)
            };
            let shards = if opts.shards > 0 {
                opts.shards
            } else {
                auto_shards
            }
            .min(workers);
            let (seq, seq_wall) = timed(1);
            let (par, par_wall) = timed(shards);
            // Events the pump delivered: one arrival per request plus one
            // completion per executed batch.
            let events = |res: &EngineResult| (n_requests + res.batches) as f64;
            let seq_eps = events(&seq) / seq_wall;
            let par_eps = events(&par) / par_wall;
            let speedup = par_eps / seq_eps.max(1e-9);
            // `None` off-Linux: print "-" and omit the JSON field rather
            // than report a garbage zero.
            let rss_mb = benchmark::peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0));
            let rss_col = rss_mb.map_or_else(|| "-".to_string(), |m| format!("{m:.0}"));
            println!(
                "{workers:>8} {models:>7} {n_requests:>9} {shards:>7} {seq_eps:>12.0} {par_eps:>12.0} {speedup:>8.2} {rss_col:>9}"
            );
            let mut fields = vec![
                ("workers", Json::num(workers as f64)),
                ("models", Json::num(models as f64)),
                ("requests", Json::num(n_requests as f64)),
                ("shards", Json::num(shards as f64)),
                ("seq_wall_s", Json::num(seq_wall)),
                ("par_wall_s", Json::num(par_wall)),
                ("seq_events_per_s", Json::num(seq_eps)),
                ("par_events_per_s", Json::num(par_eps)),
                ("seq_req_per_s", Json::num(n_requests as f64 / seq_wall)),
                ("par_req_per_s", Json::num(n_requests as f64 / par_wall)),
                ("speedup", Json::num(speedup)),
                ("seq_steps", Json::num(seq.steps as f64)),
                ("par_steps", Json::num(par.steps as f64)),
                ("batches", Json::num(seq.batches as f64)),
            ];
            if let Some(m) = rss_mb {
                fields.push(("peak_rss_mb", Json::num(m)));
            }
            rows.push(Json::obj(fields));
        }
    }
    match benchmark::json_report("BENCH_serve.json", "cluster_scale", rows.clone()) {
        Ok(p) => println!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
    Json::arr(rows)
}

/// Soft open-file limit (Linux `/proc/self/limits`), the conservative
/// 1024 elsewhere — the ingress sweep runs client and server in one
/// process, so a 10k-connection cell needs ~2× that in descriptors.
fn fd_budget() -> usize {
    #[cfg(target_os = "linux")]
    {
        if let Ok(s) = std::fs::read_to_string("/proc/self/limits") {
            for line in s.lines() {
                if line.starts_with("Max open files") {
                    if let Some(v) = line
                        .split_whitespace()
                        .nth(3)
                        .and_then(|v| v.parse::<usize>().ok())
                    {
                        return v;
                    }
                }
            }
        }
        1024
    }
    #[cfg(not(target_os = "linux"))]
    {
        1024
    }
}

/// `experiment ingress` (DESIGN.md §12): loopback wire-speed sweep.
/// Starts a real `serve --listen`-style stack in-process — sharded TCP
/// ingress feeding the orloj serving core over the lock-free arrival
/// ring, sim workers — and drives it with the open-loop `loadgen` over a
/// connections × offered-load grid. Reports sustained req/s,
/// server-side arrival→done p50/p99, client-side wire→wire p50/p99, the
/// wire tail inflation vs an in-process (mpsc, no sockets) baseline at
/// the same offered load, and the ingress drop/error counters. Rows land
/// in `BENCH_serve.json` (bench `ingress`). Conservation is asserted on
/// the server: every frame parsed off the wire is either completed by
/// the core or counted as a wire drop.
fn ingress_wire(opts: &ExpOptions) -> Json {
    use crate::clock::{us_to_ms, RealClock};
    use crate::core::request::{Completion, Outcome, Request};
    use crate::serve::ingress::{Ingress, IngressConfig};
    use crate::serve::{realtime, router, Cluster, Placement, ServingLoop};
    use crate::sim::worker::SimWorker;
    use crate::util::benchmark;
    use crate::util::stats;
    use crate::workload::loadgen::{self, LoadgenConfig};
    use std::time::{Duration, Instant};

    let quick = benchmark::quick_mode() || opts.duration_s <= 10.0;
    let (conn_grid, rate_grid, duration_s, shards): (&[usize], &[f64], f64, usize) = if quick {
        (&[16, 64], &[20_000.0], 1.2, 2)
    } else {
        (&[64, 1_000, 10_000], &[60_000.0, 150_000.0], 4.0, 4)
    };
    let shards = if opts.shards > 0 { opts.shards } else { shards };
    let workers = if opts.workers > 1 { opts.workers } else { 4 };
    let system = "orloj";
    let apps = 2usize;
    let exec_ms = 5.0;
    let slo_multiple = 10.0;
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::calibrated(exec_ms),
        ..Default::default()
    };
    let seed_spec = TraceSpec {
        name: "ingress".to_string(),
        dists: (0..apps)
            .map(|_| ExecTimeDist::constant("loadgen", exec_ms))
            .collect(),
        arrivals: AzureTraceConfig {
            apps,
            rate_per_s: 0.0,
            duration_s,
            ..Default::default()
        },
        seed: opts.seed,
        models: Vec::new(),
    };
    let build_core = |clock: RealClock| {
        let placement = Placement::parse_checked("all", workers, 1).expect("'all' always parses");
        let mut replicas =
            Cluster::build_placed(system, &cfg, opts.seed, placement).expect("known system");
        for (model, app, hist) in seed_spec.seed_histograms(cfg.bins) {
            replicas.seed_app_profile(model, app, &hist, 1000);
        }
        let core = ServingLoop::new(
            clock,
            replicas,
            router::by_name("round_robin").expect("registry has round_robin"),
        );
        let sim_workers: Vec<SimWorker> = (0..workers)
            .map(|w| SimWorker::new(cfg.cost_model, 0.0, opts.seed ^ ((w as u64) << 8)))
            .collect();
        (core, sim_workers)
    };
    let arrival_done = |completions: &[Completion]| {
        let mut lat: Vec<f64> = completions
            .iter()
            .filter(|c| matches!(c.outcome, Outcome::Finished | Outcome::Late))
            .map(|c| us_to_ms(c.at.saturating_sub(c.request.release)))
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if lat.is_empty() {
            (0.0, 0.0)
        } else {
            (
                stats::percentile_sorted(&lat, 50.0),
                stats::percentile_sorted(&lat, 99.0),
            )
        }
    };

    println!("### ingress wire-speed sweep ({system}, {workers} sim workers, {shards} shards)");
    println!(
        "{:>7} {:>11} {:>10} {:>12} {:>10} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "conns",
        "offered/s",
        "replies/s",
        "wire_drops",
        "a2d_p50ms",
        "a2d_p99ms",
        "wire_p50",
        "wire_p99",
        "inproc99",
        "inflate"
    );
    let fd_budget = fd_budget();
    let mut rows = Vec::new();
    for &rate in rate_grid {
        // In-process baseline at this offered load: same core, same sim
        // workers, same schedule — arrivals over an mpsc channel from a
        // pacing thread that re-stamps release at submit time. What the
        // wire path's tail is inflated *against*.
        let (inproc_p50, inproc_p99) = {
            let schedule: Vec<Request> = {
                let mut s = seed_spec.clone();
                s.arrivals.rate_per_s = rate;
                s.generate().requests(slo_multiple)
            };
            let clock = RealClock::new();
            let (core, sim_workers) = build_core(clock);
            let (tx, rx) = std::sync::mpsc::channel();
            let pacer = std::thread::spawn(move || {
                use crate::clock::Clock;
                let epoch = Instant::now();
                for mut r in schedule {
                    let target = r.release;
                    loop {
                        let now = epoch.elapsed().as_micros() as u64;
                        if now >= target {
                            break;
                        }
                        std::thread::sleep(Duration::from_micros((target - now).min(500)));
                    }
                    let slo = r.slo();
                    let now = clock.now();
                    r.release = now;
                    r.deadline = now + slo;
                    if tx.send(r).is_err() {
                        break;
                    }
                }
            });
            let res = realtime::serve_cluster(core, sim_workers, rx);
            pacer.join().expect("pacer panicked");
            arrival_done(&res.completions)
        };
        for &conns in conn_grid {
            // Client and server share this process: ~2 fds per
            // connection plus listener/channel slack.
            if conns * 2 + 64 > fd_budget {
                println!(
                    "{conns:>7} {rate:>11.0}  skipped: needs ~{} fds, soft limit is {fd_budget}",
                    conns * 2 + 64
                );
                continue;
            }
            let clock = RealClock::new();
            let (core, sim_workers) = build_core(clock);
            let icfg = IngressConfig {
                shards,
                ..Default::default()
            };
            let net = Ingress::bind("127.0.0.1:0", icfg, clock).expect("bind loopback");
            let addr = net.local_addr().to_string();
            let ctl = net.controller();
            let pump = std::thread::spawn(move || realtime::serve_ingress(core, sim_workers, net));
            let lg = loadgen::run(&LoadgenConfig {
                addr,
                conns,
                rate_per_s: rate,
                duration_s,
                apps,
                models: 1,
                slo_multiple,
                exec_ms,
                payload: 0,
                seed: opts.seed ^ ((conns as u64) << 24),
                workers: 0,
                drain_timeout_s: 5.0,
            })
            .expect("loadgen against loopback");
            ctl.begin_drain();
            let (res, counts) = pump.join().expect("ingress pump panicked");
            assert_eq!(
                counts.frames,
                res.completions.len() as u64 + counts.wire_drops,
                "wire conservation: every parsed frame completes or is a counted drop"
            );
            let (a2d_p50, a2d_p99) = arrival_done(&res.completions);
            let inflation = lg.wire_p99_ms / inproc_p99.max(1e-9);
            println!(
                "{conns:>7} {rate:>11.0} {:>10.0} {:>12} {a2d_p50:>10.3} {a2d_p99:>10.3} {:>10.3} {:>10.3} {inproc_p99:>10.3} {inflation:>9.2}",
                lg.reply_rps, counts.wire_drops, lg.wire_p50_ms, lg.wire_p99_ms
            );
            rows.push(Json::obj(vec![
                ("sweep", Json::str("wire")),
                ("conns", Json::num(conns as f64)),
                ("shards", Json::num(shards as f64)),
                ("workers", Json::num(workers as f64)),
                ("offered_rps", Json::num(rate)),
                ("sent", Json::num(lg.sent as f64)),
                ("frames", Json::num(counts.frames as f64)),
                ("completions", Json::num(res.completions.len() as f64)),
                ("finished", Json::num(lg.finished as f64)),
                ("late", Json::num(lg.late as f64)),
                ("shed", Json::num(lg.shed as f64)),
                ("wire_drops", Json::num(counts.wire_drops as f64)),
                ("proto_errors", Json::num(counts.proto_errors as f64)),
                ("sustained_rps", Json::num(lg.reply_rps)),
                ("arrival_done_p50_ms", Json::num(a2d_p50)),
                ("arrival_done_p99_ms", Json::num(a2d_p99)),
                ("wire_p50_ms", Json::num(lg.wire_p50_ms)),
                ("wire_p99_ms", Json::num(lg.wire_p99_ms)),
                ("inproc_p50_ms", Json::num(inproc_p50)),
                ("inproc_p99_ms", Json::num(inproc_p99)),
                ("wire_tail_inflation", Json::num(inflation)),
                (
                    "client_conservation_violations",
                    Json::num(lg.conservation_violations as f64),
                ),
            ]));
        }
    }
    // --- pump_shards sub-sweep (DESIGN.md §13): hold the offered load at
    // a rate that saturates one scheduling thread and scale the number of
    // scheduling shards; sustained req/s should climb until the workers
    // (not the scheduling loop) are the ceiling. Least-loaded routing via
    // the LoadBoard keeps this an apples-to-apples perf story against the
    // sequential pump's load-aware path. Rides the same report (one
    // json_report call — it overwrites) discriminated by `sweep`.
    let (sched_grid, pump_rate, pump_conns): (&[usize], f64, usize) = if quick {
        (&[1, 2, 4], 80_000.0, 64)
    } else {
        (&[1, 2, 4, 8], 150_000.0, 256)
    };
    let pump_workers = workers.max(sched_grid.iter().copied().max().unwrap_or(1));
    println!("### pump_shards sweep ({system}, {pump_workers} sim workers, least_loaded router)");
    println!(
        "{:>12} {:>11} {:>10} {:>12} {:>10} {:>10}",
        "sched_shards", "offered/s", "replies/s", "wire_drops", "occupancy", "a2d_p99ms"
    );
    if pump_conns * 2 + 64 > fd_budget {
        println!("  skipped: needs ~{} fds, soft limit is {fd_budget}", pump_conns * 2 + 64);
    } else {
        for &sched_shards in sched_grid {
            let clock = RealClock::new();
            let placement =
                Placement::parse_checked("all", pump_workers, 1).expect("'all' always parses");
            let mut replicas = Cluster::build_placed(system, &cfg, opts.seed, placement)
                .expect("known system");
            for (model, app, hist) in seed_spec.seed_histograms(cfg.bins) {
                replicas.seed_app_profile(model, app, &hist, 1000);
            }
            let core = ServingLoop::new(
                clock,
                replicas,
                router::by_name("least_loaded").expect("registry has least_loaded"),
            );
            let sim_workers: Vec<SimWorker> = (0..pump_workers)
                .map(|w| SimWorker::new(cfg.cost_model, 0.0, opts.seed ^ ((w as u64) << 8)))
                .collect();
            let icfg = IngressConfig {
                shards,
                ..Default::default()
            };
            let net = Ingress::bind("127.0.0.1:0", icfg, clock).expect("bind loopback");
            let addr = net.local_addr().to_string();
            let ctl = net.controller();
            let pump = std::thread::spawn(move || {
                realtime::serve_ingress_sharded(core, sim_workers, net, sched_shards)
            });
            let lg = loadgen::run(&LoadgenConfig {
                addr,
                conns: pump_conns,
                rate_per_s: pump_rate,
                duration_s,
                apps,
                models: 1,
                slo_multiple,
                exec_ms,
                payload: 0,
                seed: opts.seed ^ ((sched_shards as u64) << 16),
                workers: 0,
                drain_timeout_s: 5.0,
            })
            .expect("loadgen against loopback");
            ctl.begin_drain();
            let (res, counts) = pump.join().expect("sharded ingress pump panicked");
            assert_eq!(
                counts.frames,
                res.completions.len() as u64 + counts.wire_drops,
                "wire conservation across {sched_shards} scheduling shards"
            );
            for ss in &res.shards {
                assert!(ss.conserved(), "shard {} ledger imbalance: {ss:?}", ss.shard);
            }
            // Mean scheduling-loop occupancy; the sequential pump (S=1
            // delegates) has no shard ledger, reported as 0.
            let occupancy = if res.shards.is_empty() {
                0.0
            } else {
                res.shards.iter().map(|s| s.occupancy()).sum::<f64>() / res.shards.len() as f64
            };
            let (_, a2d_p99) = arrival_done(&res.completions);
            println!(
                "{sched_shards:>12} {pump_rate:>11.0} {:>10.0} {:>12} {:>10.3} {a2d_p99:>10.3}",
                lg.reply_rps, counts.wire_drops, occupancy
            );
            rows.push(Json::obj(vec![
                ("sweep", Json::str("pump_shards")),
                ("sched_shards", Json::num(sched_shards as f64)),
                ("shards", Json::num(shards as f64)),
                ("conns", Json::num(pump_conns as f64)),
                ("workers", Json::num(pump_workers as f64)),
                ("offered_rps", Json::num(pump_rate)),
                ("sent", Json::num(lg.sent as f64)),
                ("frames", Json::num(counts.frames as f64)),
                ("completions", Json::num(res.completions.len() as f64)),
                ("wire_drops", Json::num(counts.wire_drops as f64)),
                ("sustained_rps", Json::num(lg.reply_rps)),
                ("sched_occupancy", Json::num(occupancy)),
                ("arrival_done_p99_ms", Json::num(a2d_p99)),
                (
                    "client_conservation_violations",
                    Json::num(lg.conservation_violations as f64),
                ),
            ]));
        }
    }

    match benchmark::json_report("BENCH_serve.json", "ingress", rows.clone()) {
        Ok(p) => println!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
    Json::arr(rows)
}

/// Run one experiment by id; returns its JSON rows.
pub fn run(id: &str, opts: &ExpOptions) -> Option<Json> {
    let rows = match id {
        "fig2" => fig2(opts),
        "fig3" => fig3(opts),
        "fig6" => fig6(opts),
        "table2" | "fig9" | "fig10" => table2(opts),
        "table3" | "fig8" => table3(opts),
        "table4" | "fig11" => table4(opts),
        "table5" | "fig7" => table5(opts),
        "fig13" => fig13(opts),
        "fig14" => fig14(opts),
        "multimodel" => multimodel(opts),
        "elastic" => elastic(opts),
        "ablation" => ablation(opts),
        "overload" => overload(opts),
        "cluster" => cluster_scale(opts),
        "ingress" => ingress_wire(opts),
        _ => return None,
    };
    Some(rows)
}

/// All experiment ids in run order.
pub const ALL: [&str; 15] = [
    "fig2", "fig3", "fig6", "table2", "table3", "table4", "table5", "fig13", "fig14", "multimodel",
    "elastic", "ablation", "overload", "cluster", "ingress",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_runs() {
        let j = fig6(&ExpOptions::quick());
        assert!(j.get("batch_mean").as_f64().unwrap() > 5.0);
    }

    #[test]
    fn fig2_reports_all_tasks() {
        let j = fig2(&ExpOptions::quick());
        assert_eq!(j.as_arr().unwrap().len(), 12); // 10 dynamic + 2 static
    }

    #[test]
    fn quick_grid_experiment_has_sane_shape() {
        let opts = ExpOptions::quick();
        let j = fig3(&opts);
        let cases = j.as_arr().unwrap();
        assert_eq!(cases.len(), 3);
        // 2 SLOs × 5 systems per case.
        assert_eq!(cases[0].as_arr().unwrap().len(), 10);
        for row in cases[0].as_arr().unwrap() {
            let fr = row.get("finish_rate").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&fr));
            assert_eq!(row.get("workers").as_f64().unwrap(), 1.0);
        }
    }

    #[test]
    fn multimodel_quick_reports_per_model_rates() {
        let mut opts = ExpOptions::quick();
        opts.duration_s = 6.0;
        opts.slos = vec![3.0];
        opts.workers = 2;
        opts.models = 2;
        opts.placement = "skewed".into();
        let j = multimodel(&opts);
        let cases = j.as_arr().unwrap();
        assert_eq!(cases.len(), 3, "even + two skew levels");
        for case in cases {
            // 1 SLO × 5 systems per case.
            let rows = case.as_arr().unwrap();
            assert_eq!(rows.len(), 5);
            for row in rows {
                let pm = row.get("per_model").as_arr().unwrap();
                assert_eq!(pm.len(), 2, "two models per cell");
                for entry in pm {
                    let fr = entry.get("finish_rate").as_f64().unwrap();
                    assert!((0.0..=1.0).contains(&fr));
                    assert!(entry.get("total").as_f64().unwrap() > 0.0);
                }
            }
        }
    }

    #[test]
    fn elastic_quick_compares_static_and_elastic_modes() {
        let mut opts = ExpOptions::quick();
        opts.duration_s = 6.0;
        opts.slos = vec![3.0];
        opts.drift_period_s = 3.0;
        opts.capacity = 1;
        let j = elastic(&opts);
        let cases = j.as_arr().unwrap();
        assert_eq!(cases.len(), 2, "two skew levels");
        for case in cases {
            let rows = case.as_arr().unwrap();
            // 5 systems × (2 static placements + 1 elastic).
            assert_eq!(rows.len(), 15);
            let mut elastic_rows = 0;
            for row in rows {
                let fr = row.get("finish_rate").as_f64().unwrap();
                assert!((0.0..=1.0).contains(&fr), "finish_rate={fr}");
                if row.get("mode").as_str() == Some("elastic") {
                    elastic_rows += 1;
                    assert!(row.get("load_actions").as_f64().unwrap() >= 0.0);
                    assert!(row.get("converge_s").as_f64().unwrap() >= 0.0);
                    assert!(row.get("best_static").as_f64().is_some());
                }
            }
            assert_eq!(elastic_rows, 5);
        }
    }

    #[test]
    fn telemetry_option_exports_series_and_chrome_trace() {
        let mut opts = ExpOptions::quick();
        opts.duration_s = 4.0;
        opts.slos = vec![3.0];
        let dir = std::env::temp_dir().join("orloj_exp_telemetry_test");
        let _ = std::fs::remove_dir_all(&dir);
        opts.telemetry = Some(dir.to_string_lossy().into_owned());
        let (spec, cfg) = spec_for("tel", modal_apps(2, 1.0, None), &opts, 0x77);
        let cells = runner::run_grid(
            &["orloj"],
            &spec,
            &opts.slos,
            &cfg,
            spec.seed,
            &opts.cluster(),
        );
        assert!(cells[0].telemetry.is_some(), "cluster() must enable capture");
        print_grid("tel-case", &cells, &opts);
        let ts = std::fs::read_to_string(dir.join("TELEMETRY_tel-case.json")).unwrap();
        let parsed = Json::parse(&ts).unwrap();
        assert!(!parsed.as_arr().unwrap().is_empty());
        let tr = std::fs::read_to_string(dir.join("TELEMETRY_tel-case.trace.json")).unwrap();
        let trace = Json::parse(&tr).unwrap();
        assert!(!trace.get("traceEvents").as_arr().unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overload_quick_compares_admission_against_shed_baseline() {
        let mut opts = ExpOptions::quick();
        opts.duration_s = 5.0;
        opts.slos = vec![2.0];
        let j = overload(&opts);
        let cases = j.as_arr().unwrap();
        assert_eq!(cases.len(), 3, "quick sweep: three loads");
        for case in cases {
            let rows = case.as_arr().unwrap();
            assert_eq!(rows.len(), 5, "all five systems per load");
            for row in rows {
                let shed = row.get("shed_finish_rate").as_f64().unwrap();
                let adm = row.get("adm_finish_rate").as_f64().unwrap();
                assert!((0.0..=1.0).contains(&shed));
                assert!((0.0..=1.0).contains(&adm));
                assert!(row.get("shed_wasted_ms").as_f64().unwrap() >= 0.0);
                assert!(row.get("adm_wasted_ms").as_f64().unwrap() >= 0.0);
            }
        }
        // At 3x offered load the gate must actually engage for orloj:
        // something gets downgraded or rejected rather than queued.
        let last = cases.last().unwrap().as_arr().unwrap();
        let orloj = last
            .iter()
            .find(|r| r.get("system").as_str() == Some("orloj"))
            .unwrap();
        let gated = orloj.get("downgraded").as_f64().unwrap()
            + orloj.get("early_rejected").as_f64().unwrap();
        assert!(gated > 0.0, "3x overload must downgrade or reject");
    }

    #[test]
    fn multi_worker_quick_grid_reports_utilizations() {
        let mut opts = ExpOptions::quick();
        opts.duration_s = 6.0;
        opts.slos = vec![3.0];
        opts.workers = 2;
        opts.router = "join_shortest_queue".into();
        let j = fig3(&opts);
        let cases = j.as_arr().unwrap();
        for row in cases[0].as_arr().unwrap() {
            assert_eq!(row.get("workers").as_f64().unwrap(), 2.0);
            let utils = row.get("per_worker_utilization");
            assert_eq!(utils.as_arr().unwrap().len(), 2);
        }
    }
}
