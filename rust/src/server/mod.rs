//! Real-time serving runtime — a thin shim over the unified serving core
//! (`serve::ServingLoop` + the wall-clock pump in `serve::realtime`;
//! threads, no tokio in the offline vendored set — see DESIGN.md §3).
//!
//! An intake channel feeds the scheduling loop, which routes arrivals
//! across N replicas and runs each replica's worker on its own thread.
//! Used by the PJRT end-to-end examples; the evaluation sweeps use the
//! virtual-time pump in `serve::replay`.

pub mod metrics;

use crate::clock::RealClock;
use crate::core::request::Request;
use crate::scheduler::Scheduler;
use crate::serve::ingress::{Ingress, IngressConfig, IngressController, IngressCounts};
use crate::serve::realtime::{self, ServeResult};
use crate::serve::router::{self, Router};
use crate::serve::{AdmissionController, Cluster, Placement, PlacementController, ServingLoop};
use crate::sim::worker::Worker;
use std::sync::mpsc::{self, Receiver, Sender};

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<Request>,
}

impl Submitter {
    pub fn submit(&self, req: Request) -> bool {
        self.tx.send(req).is_ok()
    }
}

/// A serving cluster (the paper's per-GPU scheduler, §3.1, × N replicas).
///
/// Arrivals come in through an mpsc channel from any number of client
/// threads; a router assigns each to a replica, and every replica's worker
/// executes on its own thread. Returns all completions plus per-replica
/// stats when the channel closes and queues drain.
pub struct Server<S: Scheduler, W: Worker> {
    scheds: Vec<S>,
    workers: Vec<W>,
    router: Box<dyn Router>,
    /// Which models each replica hosts (None = every replica hosts every
    /// model, the historical single-model behaviour).
    placement: Option<Placement>,
    /// Elastic placement controller (requires `with_placement`).
    elastic: Option<PlacementController>,
    /// Predictive admission gate (off by default; DESIGN.md §10).
    admission: Option<AdmissionController>,
    /// Lifecycle recorder handed to the serving loop (off by default).
    telemetry: Option<crate::telemetry::Recorder>,
    /// Anchored at construction so callers can stamp release times before
    /// the serving thread spins up.
    clock: RealClock,
    /// Scheduling shards for the network pump (1 = the sequential pump;
    /// see [`Server::with_shards`]).
    shards: usize,
}

impl<S: Scheduler, W: Worker> Server<S, W> {
    /// A single-replica server (the historical single-GPU loop).
    pub fn new(sched: S, worker: W) -> Self {
        Server {
            scheds: vec![sched],
            workers: vec![worker],
            router: router::by_name("round_robin").expect("registry has round_robin"),
            placement: None,
            elastic: None,
            admission: None,
            telemetry: None,
            clock: RealClock::new(),
            shards: 1,
        }
    }

    /// An N-replica server: one `(scheduler, worker)` pair per replica,
    /// with `router` picking the replica for each arrival.
    pub fn cluster(replicas: Vec<(S, W)>, router: Box<dyn Router>) -> Self {
        let (scheds, workers): (Vec<S>, Vec<W>) = replicas.into_iter().unzip();
        Server {
            scheds,
            workers,
            router,
            placement: None,
            elastic: None,
            admission: None,
            telemetry: None,
            clock: RealClock::new(),
            shards: 1,
        }
    }

    /// Run the network pump as `n` independent scheduling shards, each
    /// owning a contiguous block of replicas on its own thread with
    /// load-aware routing over the lock-free `LoadBoard` (DESIGN.md §13).
    /// Applies to [`BoundServer::run`] only; `n <= 1` — and any
    /// configuration the shards can't split (elastic, admission,
    /// telemetry, an unmapped router) — uses the sequential pump.
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    /// Constrain which models each replica hosts (the router only routes a
    /// request to replicas hosting its model).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        assert_eq!(placement.workers(), self.scheds.len());
        self.placement = Some(placement);
        self
    }

    /// Enable elastic placement: `ctl` rebalances model hosting at
    /// runtime (loads run on the worker threads; see `serve::realtime`).
    /// Requires an explicit placement via [`Server::with_placement`].
    pub fn with_elastic(mut self, ctl: PlacementController) -> Self {
        assert!(
            self.placement.is_some(),
            "elastic serving needs with_placement first"
        );
        self.elastic = Some(ctl);
        self
    }

    /// Gate arrivals through predictive admission control (`ctl` decides
    /// admit / best-effort downgrade / early-reject per arrival; the
    /// tallies come back on [`ServeResult::admission`]; DESIGN.md §10).
    pub fn with_admission(mut self, ctl: AdmissionController) -> Self {
        self.admission = Some(ctl);
        self
    }

    /// Record request-lifecycle telemetry into `rec`; the filled recorder
    /// comes back on [`ServeResult::telemetry`].
    pub fn with_telemetry(mut self, rec: crate::telemetry::Recorder) -> Self {
        self.telemetry = Some(rec);
        self
    }

    /// Create the submission channel. Call before `run`.
    pub fn channel() -> (Submitter, Receiver<Request>) {
        let (tx, rx) = mpsc::channel();
        (Submitter { tx }, rx)
    }

    /// Current server-relative time (µs since construction).
    pub fn now(&self) -> crate::clock::Micros {
        use crate::clock::Clock;
        self.clock.now()
    }

    /// Serve until the submitters hang up and everything drains.
    pub fn run(self, rx: Receiver<Request>) -> ServeResult {
        let cluster = match self.placement {
            Some(p) => Cluster::with_placement(self.scheds, p),
            None => Cluster::new(self.scheds),
        };
        let mut core = ServingLoop::new(self.clock, cluster, self.router);
        if let Some(ctl) = self.elastic {
            core = core.with_elastic(ctl);
        }
        if let Some(ctl) = self.admission {
            core = core.with_admission(ctl);
        }
        if let Some(rec) = self.telemetry {
            core = core.with_telemetry(rec);
        }
        realtime::serve_cluster(core, self.workers, rx)
    }

    /// Bind the network front end (DESIGN.md §12) on `addr` and return a
    /// [`BoundServer`] ready to pump it. Two-phase so the caller can grab
    /// the bound address and an [`IngressController`] (SIGINT watchers,
    /// `--duration` timers) before [`BoundServer::run`] blocks. The
    /// ingress shards stamp release times on this server's clock, so
    /// wire timestamps and core timestamps share one epoch.
    pub fn listen(self, addr: &str, cfg: IngressConfig) -> std::io::Result<BoundServer<S, W>> {
        let net = Ingress::bind(addr, cfg, self.clock)?;
        Ok(BoundServer { server: self, net })
    }
}

/// A [`Server`] with its network ingress bound and its shard threads
/// already accepting; [`BoundServer::run`] starts the serving pump.
pub struct BoundServer<S: Scheduler, W: Worker> {
    server: Server<S, W>,
    net: Ingress,
}

impl<S: Scheduler, W: Worker> BoundServer<S, W> {
    /// The bound socket address (useful with `:0`).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.net.local_addr()
    }

    /// A drain/shutdown handle, cloneable into watcher threads.
    pub fn controller(&self) -> IngressController {
        self.net.controller()
    }

    /// Serve the wire until a drain is requested and everything in flight
    /// completes; returns the serve result plus the ingress counters.
    pub fn run(self) -> (ServeResult, IngressCounts) {
        let s = self.server;
        let cluster = match s.placement {
            Some(p) => Cluster::with_placement(s.scheds, p),
            None => Cluster::new(s.scheds),
        };
        let mut core = ServingLoop::new(s.clock, cluster, s.router);
        if let Some(ctl) = s.elastic {
            core = core.with_elastic(ctl);
        }
        if let Some(ctl) = s.admission {
            core = core.with_admission(ctl);
        }
        if let Some(rec) = s.telemetry {
            core = core.with_telemetry(rec);
        }
        realtime::serve_ingress_sharded(core, s.workers, self.net, s.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::sim::worker::SimWorker;
    use std::time::Duration;

    /// A worker that actually sleeps (real time) scaled down hard so the
    /// test stays fast.
    struct SleepWorker;
    impl Worker for SleepWorker {
        fn execute(&mut self, batch: &[Request]) -> f64 {
            let ms = 0.2 + 0.05 * batch.len() as f64;
            std::thread::sleep(Duration::from_micros((ms * 1000.0) as u64));
            ms
        }
    }

    fn edf(cost: BatchCostModel) -> EdfScheduler {
        let cfg = SchedulerConfig {
            cost_model: cost,
            ..Default::default()
        };
        let mut sched = EdfScheduler::new(cfg, 0);
        sched.seed_exec_mean(1.0);
        sched
    }

    #[test]
    fn serves_from_channel_and_drains() {
        let sched = edf(BatchCostModel::new(0.2, 0.05));
        let (submitter, rx) = Server::<EdfScheduler, SleepWorker>::channel();
        let server = Server::new(sched, SleepWorker);

        let handle = std::thread::spawn(move || server.run(rx));
        for i in 0..20u64 {
            submitter.submit(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0));
            std::thread::sleep(Duration::from_micros(200));
        }
        drop(submitter);
        let res = handle.join().unwrap();
        assert_eq!(res.completions.len(), 20);
        assert_eq!(res.per_worker.len(), 1);
        let finished = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert!(finished >= 18, "finished={finished}");
    }

    #[test]
    fn two_replica_cluster_splits_the_work() {
        let replicas: Vec<(EdfScheduler, SleepWorker)> = (0..2)
            .map(|_| (edf(BatchCostModel::new(0.2, 0.05)), SleepWorker))
            .collect();
        let (submitter, rx) = Server::<EdfScheduler, SleepWorker>::channel();
        let server = Server::cluster(replicas, router::by_name("round_robin").unwrap());
        let handle = std::thread::spawn(move || server.run(rx));
        for i in 0..30u64 {
            submitter.submit(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0));
            std::thread::sleep(Duration::from_micros(150));
        }
        drop(submitter);
        let res = handle.join().unwrap();
        assert_eq!(res.completions.len(), 30, "conservation across replicas");
        assert_eq!(res.per_worker.len(), 2);
        assert!(
            res.per_worker.iter().all(|w| w.batches > 0),
            "round-robin must exercise both replicas: {:?}",
            res.per_worker
        );
    }

    #[test]
    fn sim_worker_compatible() {
        // The Server generic works with the SimWorker too (zero real time,
        // still functional).
        let cfg = SchedulerConfig::default();
        let mut sched = EdfScheduler::new(cfg, 0);
        sched.seed_exec_mean(1.0);
        let (submitter, rx) = Server::<EdfScheduler, SimWorker>::channel();
        let server = Server::new(
            sched,
            SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0),
        );
        let handle = std::thread::spawn(move || server.run(rx));
        for i in 0..5u64 {
            submitter.submit(Request::new(i, AppId(0), 0, ms_to_us(10_000.0), 1.0));
        }
        drop(submitter);
        let res = handle.join().unwrap();
        assert_eq!(res.completions.len(), 5);
    }
}
