//! Real-time serving runtime (threads, no tokio in the offline vendored
//! set — see DESIGN.md §3): an intake channel feeding the scheduler loop,
//! which drives one worker. Used by the PJRT end-to-end examples; the
//! evaluation sweeps use the virtual-time engine in `sim`.

pub mod metrics;

use crate::clock::{Clock, Micros, RealClock};
use crate::core::request::{Completion, Outcome, Request};
use crate::scheduler::Scheduler;
use crate::sim::worker::Worker;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<Request>,
}

impl Submitter {
    pub fn submit(&self, req: Request) -> bool {
        self.tx.send(req).is_ok()
    }
}

/// A single-worker serving loop (the paper's per-GPU scheduler, §3.1).
///
/// Runs the scheduler and the worker on the calling thread; arrivals come
/// in through an mpsc channel from any number of client threads. Returns
/// all completions when the channel closes and queues drain.
pub struct Server<S: Scheduler, W: Worker> {
    sched: S,
    worker: W,
    clock: RealClock,
}

impl<S: Scheduler, W: Worker> Server<S, W> {
    pub fn new(sched: S, worker: W) -> Self {
        Server {
            sched,
            worker,
            clock: RealClock::new(),
        }
    }

    /// Create the submission channel. Call before `run`.
    pub fn channel() -> (Submitter, Receiver<Request>) {
        let (tx, rx) = mpsc::channel();
        (Submitter { tx }, rx)
    }

    /// Current server-relative time (µs since construction).
    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    /// Serve until the submitters hang up and everything drains.
    pub fn run(mut self, rx: Receiver<Request>) -> Vec<Completion> {
        let mut completions = Vec::new();
        let mut open = true;
        loop {
            let now = self.clock.now();
            // Pull everything currently in the channel.
            loop {
                match rx.try_recv() {
                    Ok(req) => self.sched.on_arrival(req, now),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            for (r, outcome) in self.sched.drain_dropped() {
                completions.push(Completion {
                    request: r,
                    outcome,
                    at: now,
                    batch_size: 0,
                });
            }
            // Dispatch (the worker call blocks this thread — single-GPU
            // semantics: non-preemptive batch execution).
            if let Some(batch) = self.sched.next_batch(now) {
                let batch_ms = self.worker.execute(&batch);
                let done = self.clock.now();
                let bs = batch.len();
                for r in &batch {
                    let outcome = if done <= r.deadline {
                        Outcome::Finished
                    } else {
                        Outcome::Late
                    };
                    completions.push(Completion {
                        request: r.clone(),
                        outcome,
                        at: done,
                        batch_size: bs,
                    });
                }
                self.sched.on_batch_complete(&batch, batch_ms, done);
                continue;
            }
            if !open && self.sched.pending() == 0 {
                break;
            }
            // Idle: block briefly for new arrivals or the next wake hint.
            let wait_us = self
                .sched
                .wake_hint(now)
                .map(|h| h.saturating_sub(now).clamp(100, 5_000))
                .unwrap_or(1_000);
            match rx.recv_timeout(Duration::from_micros(wait_us)) {
                Ok(req) => {
                    let t = self.clock.now();
                    self.sched.on_arrival(req, t);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                }
            }
        }
        completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;
    use crate::scheduler::SchedulerConfig;
    use crate::sim::worker::SimWorker;

    /// A worker that actually sleeps (real time) scaled down hard so the
    /// test stays fast.
    struct SleepWorker;
    impl Worker for SleepWorker {
        fn execute(&mut self, batch: &[Request]) -> f64 {
            let ms = 0.2 + 0.05 * batch.len() as f64;
            std::thread::sleep(Duration::from_micros((ms * 1000.0) as u64));
            ms
        }
    }

    #[test]
    fn serves_from_channel_and_drains() {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.2, 0.05),
            ..Default::default()
        };
        let mut sched = EdfScheduler::new(cfg, 0);
        sched.seed_exec_mean(1.0);
        let (submitter, rx) = Server::<EdfScheduler, SleepWorker>::channel();
        let server = Server::new(sched, SleepWorker);

        let handle = std::thread::spawn(move || server.run(rx));
        for i in 0..20u64 {
            submitter.submit(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0));
            std::thread::sleep(Duration::from_micros(200));
        }
        drop(submitter);
        let completions = handle.join().unwrap();
        assert_eq!(completions.len(), 20);
        let finished = completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert!(finished >= 18, "finished={finished}");
    }

    #[test]
    fn sim_worker_compatible() {
        // The Server generic works with the SimWorker too (zero real time,
        // still functional).
        let cfg = SchedulerConfig::default();
        let mut sched = EdfScheduler::new(cfg, 0);
        sched.seed_exec_mean(1.0);
        let (submitter, rx) =
            Server::<EdfScheduler, SimWorker>::channel();
        let server = Server::new(
            sched,
            SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0),
        );
        let handle = std::thread::spawn(move || server.run(rx));
        for i in 0..5u64 {
            submitter.submit(Request::new(i, AppId(0), 0, ms_to_us(10_000.0), 1.0));
        }
        drop(submitter);
        let completions = handle.join().unwrap();
        assert_eq!(completions.len(), 5);
    }
}
