//! Serving metrics: the paper's *finish rate* (§5.2 Metrics) plus latency
//! summaries and per-app/per-outcome breakdowns.

use crate::clock::Micros;
use crate::core::request::{AppId, Completion, Outcome};
use crate::serve::WorkerStats;
use crate::util::stats::Summary;
use std::collections::BTreeMap;

/// Per-replica utilization and batch counts for a serving run.
#[derive(Debug, Clone)]
pub struct WorkerUtil {
    pub worker: usize,
    pub batches: usize,
    pub busy_us: Micros,
    /// Busy fraction of the run.
    pub utilization: f64,
}

/// Aggregated result of a serving run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub total: usize,
    pub finished: usize,
    pub late: usize,
    pub timed_out: usize,
    pub aborted: usize,
    /// Latency summary over completed (finished + late) requests, ms.
    pub latency: Summary,
    /// Mean batch size over executed batches.
    pub mean_batch_size: f64,
    /// Per-app finish rates.
    pub per_app: BTreeMap<u32, (usize, usize)>, // app -> (finished, total)
    /// Per-replica execution stats (empty when the run didn't report any —
    /// e.g. a report built from completions alone).
    pub per_worker: Vec<WorkerUtil>,
}

impl RunReport {
    /// Finish rate: requests completed within their SLO / total (§5.2).
    pub fn finish_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.finished as f64 / self.total as f64
        }
    }

    pub fn from_completions(completions: &[Completion]) -> RunReport {
        let mut finished = 0;
        let mut late = 0;
        let mut timed_out = 0;
        let mut aborted = 0;
        let mut latencies = Vec::new();
        let mut per_app: BTreeMap<u32, (usize, usize)> = BTreeMap::new();
        let mut batch_sizes = Vec::new();
        for c in completions {
            let AppId(app) = c.request.app;
            let slot = per_app.entry(app).or_insert((0, 0));
            slot.1 += 1;
            match c.outcome {
                Outcome::Finished => {
                    finished += 1;
                    slot.0 += 1;
                    latencies.push(c.latency_ms());
                    batch_sizes.push(c.batch_size as f64);
                }
                Outcome::Late => {
                    late += 1;
                    latencies.push(c.latency_ms());
                    batch_sizes.push(c.batch_size as f64);
                }
                Outcome::TimedOut => timed_out += 1,
                Outcome::Aborted => aborted += 1,
            }
        }
        RunReport {
            total: completions.len(),
            finished,
            late,
            timed_out,
            aborted,
            latency: Summary::of(&latencies),
            mean_batch_size: crate::util::stats::mean(&batch_sizes),
            per_app,
            per_worker: Vec::new(),
        }
    }

    /// Attach per-replica execution counters (from `EngineResult` /
    /// `ServeResult`); `end_time` is the run length in µs.
    pub fn with_worker_stats(mut self, stats: &[WorkerStats], end_time: Micros) -> RunReport {
        self.per_worker = stats
            .iter()
            .map(|s| WorkerUtil {
                worker: s.worker,
                batches: s.batches,
                busy_us: s.busy_us,
                utilization: s.utilization(end_time),
            })
            .collect();
        self
    }
}

impl std::fmt::Display for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "finish_rate={:.3} (fin={} late={} timeout={} abort={} total={}) lat_p50={:.1}ms lat_p99={:.1}ms mean_bs={:.1}",
            self.finish_rate(),
            self.finished,
            self.late,
            self.timed_out,
            self.aborted,
            self.total,
            self.latency.p50,
            self.latency.p99,
            self.mean_batch_size
        )?;
        if !self.per_worker.is_empty() {
            let utils: Vec<String> = self
                .per_worker
                .iter()
                .map(|w| format!("w{}={:.2}/{}b", w.worker, w.utilization, w.batches))
                .collect();
            write!(f, " util=[{}]", utils.join(" "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::Request;

    fn comp(id: u64, app: u32, outcome: Outcome, at: u64) -> Completion {
        Completion {
            request: Request::new(id, AppId(app), 0, 1_000_000, 5.0),
            outcome,
            at,
            batch_size: 4,
        }
    }

    #[test]
    fn finish_rate_and_breakdown() {
        let comps = vec![
            comp(1, 0, Outcome::Finished, 100),
            comp(2, 0, Outcome::Late, 2_000_000),
            comp(3, 1, Outcome::TimedOut, 500),
            comp(4, 1, Outcome::Finished, 900),
            comp(5, 1, Outcome::Aborted, 900),
        ];
        let r = RunReport::from_completions(&comps);
        assert_eq!(r.total, 5);
        assert_eq!(r.finished, 2);
        assert!((r.finish_rate() - 0.4).abs() < 1e-12);
        assert_eq!(r.per_app[&0], (1, 2));
        assert_eq!(r.per_app[&1], (1, 3));
        assert_eq!(r.timed_out, 1);
        assert_eq!(r.aborted, 1);
    }

    #[test]
    fn empty_report() {
        let r = RunReport::from_completions(&[]);
        assert_eq!(r.finish_rate(), 0.0);
        assert_eq!(r.total, 0);
        assert!(r.per_worker.is_empty());
    }

    #[test]
    fn worker_stats_become_utilizations() {
        let stats = vec![
            WorkerStats {
                worker: 0,
                batches: 10,
                busy_us: 500,
            },
            WorkerStats {
                worker: 1,
                batches: 4,
                busy_us: 250,
            },
        ];
        let r = RunReport::from_completions(&[]).with_worker_stats(&stats, 1_000);
        assert_eq!(r.per_worker.len(), 2);
        assert!((r.per_worker[0].utilization - 0.5).abs() < 1e-12);
        assert!((r.per_worker[1].utilization - 0.25).abs() < 1e-12);
        let shown = format!("{r}");
        assert!(shown.contains("w0=0.50/10b"), "{shown}");
    }
}
