//! Serving metrics: the paper's *finish rate* (§5.2 Metrics) plus latency
//! summaries and per-app / per-model / per-outcome breakdowns.

use crate::clock::Micros;
use crate::core::request::{AppId, Completion, Outcome};
use crate::serve::WorkerStats;
use crate::util::stats::Summary;
use std::collections::BTreeMap;

/// Per-replica utilization and batch counts for a serving run.
#[derive(Debug, Clone)]
pub struct WorkerUtil {
    pub worker: usize,
    pub batches: usize,
    pub busy_us: Micros,
    /// Busy fraction of the run.
    pub utilization: f64,
}

/// Per-model finish-rate and latency breakdown.
#[derive(Debug, Clone)]
pub struct ModelRates {
    pub finished: usize,
    pub total: usize,
    /// Latency summary over this model's serviced (finished + late)
    /// requests, ms — the same outcome set as [`RunReport::latency`].
    pub latency: Summary,
}

impl ModelRates {
    pub fn finish_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.finished as f64 / self.total as f64
        }
    }
}

/// Aggregated result of a serving run.
///
/// Outcome semantics (uniform across every summary in this report):
/// * `finished` counts [`Outcome::Finished`] only — the paper's finish
///   rate numerator (§5.2).
/// * *Serviced* requests — `Finished` **and** `Late` — feed every latency
///   summary (global and per-model) and `mean_batch_size`: they ran on a
///   worker, so they have a real latency and a real batch. `TimedOut` and
///   `Aborted` requests never executed and contribute to counts only.
/// * Best-effort completions (admission-control downgrades; DESIGN.md §10)
///   are carved out of every SLO tally: they count in `total` and
///   `best_effort` only, and never move the finish rate in either
///   direction. With admission off, `best_effort` is zero and every number
///   here is bit-identical to the pre-admission report.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub total: usize,
    pub finished: usize,
    pub late: usize,
    pub timed_out: usize,
    pub aborted: usize,
    /// Completions served from (or drained out of) the best-effort lane.
    pub best_effort: usize,
    /// GPU time (solo exec ms, the batch-amortization-free proxy) spent
    /// executing requests that still missed their deadline — the overload
    /// experiment's wasted-work metric. Both lanes count: a late SLO batch
    /// and a late best-effort batch both burned the GPU for nothing.
    pub wasted_ms: f64,
    /// Latency summary over serviced (finished + late) requests, ms.
    pub latency: Summary,
    /// Mean batch size over serviced requests (request-weighted, not
    /// batch-weighted: a size-8 batch contributes 8 samples of 8).
    pub mean_batch_size: f64,
    /// Per-app finish rates.
    pub per_app: BTreeMap<u32, (usize, usize)>, // app -> (finished, total)
    /// Per-model finish-rate and latency breakdown (one entry per model
    /// seen in the completions; single-model runs have exactly one).
    pub per_model: BTreeMap<u32, ModelRates>,
    /// Per-replica execution stats (empty when the run didn't report any —
    /// e.g. a report built from completions alone).
    pub per_worker: Vec<WorkerUtil>,
}

impl RunReport {
    /// Finish rate: requests completed within their SLO / total (§5.2).
    /// Best-effort completions are outside the SLO contract and leave the
    /// denominator (identical to total when admission is off).
    pub fn finish_rate(&self) -> f64 {
        let slo_total = self.total - self.best_effort;
        if slo_total == 0 {
            0.0
        } else {
            self.finished as f64 / slo_total as f64
        }
    }

    pub fn from_completions(completions: &[Completion]) -> RunReport {
        let mut finished = 0;
        let mut late = 0;
        let mut timed_out = 0;
        let mut aborted = 0;
        let mut best_effort = 0;
        let mut wasted_ms = 0.0;
        let mut latencies = Vec::new();
        let mut per_app: BTreeMap<u32, (usize, usize)> = BTreeMap::new();
        let mut per_model_acc: BTreeMap<u32, (usize, usize, Vec<f64>)> = BTreeMap::new();
        let mut batch_sizes = Vec::new();
        for c in completions {
            if c.batch_size > 0 && c.outcome == Outcome::Late {
                wasted_ms += c.request.exec_ms;
            }
            if c.best_effort {
                best_effort += 1;
                continue;
            }
            let AppId(app) = c.request.app;
            let slot = per_app.entry(app).or_insert((0, 0));
            slot.1 += 1;
            let mslot = per_model_acc
                .entry(c.request.model.0)
                .or_insert_with(|| (0, 0, Vec::new()));
            mslot.1 += 1;
            match c.outcome {
                Outcome::Finished => {
                    finished += 1;
                    slot.0 += 1;
                    mslot.0 += 1;
                    mslot.2.push(c.latency_ms());
                    latencies.push(c.latency_ms());
                    batch_sizes.push(c.batch_size as f64);
                }
                Outcome::Late => {
                    late += 1;
                    mslot.2.push(c.latency_ms());
                    latencies.push(c.latency_ms());
                    batch_sizes.push(c.batch_size as f64);
                }
                Outcome::TimedOut => timed_out += 1,
                Outcome::Aborted => aborted += 1,
            }
        }
        let per_model = per_model_acc
            .into_iter()
            .map(|(m, (fin, total, lats))| {
                (
                    m,
                    ModelRates {
                        finished: fin,
                        total,
                        latency: Summary::of(&lats),
                    },
                )
            })
            .collect();
        RunReport {
            total: completions.len(),
            finished,
            late,
            timed_out,
            aborted,
            best_effort,
            wasted_ms,
            latency: Summary::of(&latencies),
            mean_batch_size: crate::util::stats::mean(&batch_sizes),
            per_app,
            per_model,
            per_worker: Vec::new(),
        }
    }

    /// Attach per-replica execution counters (from `EngineResult` /
    /// `ServeResult`); `end_time` is the run length in µs.
    pub fn with_worker_stats(mut self, stats: &[WorkerStats], end_time: Micros) -> RunReport {
        self.per_worker = stats
            .iter()
            .map(|s| WorkerUtil {
                worker: s.worker,
                batches: s.batches,
                busy_us: s.busy_us,
                utilization: s.utilization(end_time),
            })
            .collect();
        self
    }
}

impl std::fmt::Display for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "finish_rate={:.3} (fin={} late={} timeout={} abort={} total={}) lat_p50={:.1}ms lat_p99={:.1}ms mean_bs={:.1}",
            self.finish_rate(),
            self.finished,
            self.late,
            self.timed_out,
            self.aborted,
            self.total,
            self.latency.p50,
            self.latency.p99,
            self.mean_batch_size
        )?;
        if self.best_effort > 0 {
            write!(f, " be={}", self.best_effort)?;
        }
        if self.wasted_ms > 0.0 {
            write!(f, " wasted={:.0}ms", self.wasted_ms)?;
        }
        // Always show the per-model line when the breakdown exists —
        // hiding it on single-model runs made `m0`'s latency detail
        // unreachable from the printed report.
        if !self.per_model.is_empty() {
            let rates: Vec<String> = self
                .per_model
                .iter()
                .map(|(m, r)| {
                    format!("m{}={:.2}/{}r/p99={:.0}ms", m, r.finish_rate(), r.total, r.latency.p99)
                })
                .collect();
            write!(f, " models=[{}]", rates.join(" "))?;
        }
        if !self.per_worker.is_empty() {
            let utils: Vec<String> = self
                .per_worker
                .iter()
                .map(|w| format!("w{}={:.2}/{}b", w.worker, w.utilization, w.batches))
                .collect();
            write!(f, " util=[{}]", utils.join(" "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ModelId, Request};

    fn comp(id: u64, app: u32, outcome: Outcome, at: u64) -> Completion {
        Completion {
            request: Request::new(id, AppId(app), 0, 1_000_000, 5.0),
            outcome,
            at,
            batch_size: 4,
            worker: Some(0),
            best_effort: false,
        }
    }

    fn comp_model(id: u64, model: u32, outcome: Outcome, at: u64) -> Completion {
        Completion {
            request: Request::new(id, AppId(0), 0, 1_000_000, 5.0).with_model(ModelId(model)),
            outcome,
            at,
            batch_size: 2,
            worker: Some(0),
            best_effort: false,
        }
    }

    #[test]
    fn finish_rate_and_breakdown() {
        let comps = vec![
            comp(1, 0, Outcome::Finished, 100),
            comp(2, 0, Outcome::Late, 2_000_000),
            comp(3, 1, Outcome::TimedOut, 500),
            comp(4, 1, Outcome::Finished, 900),
            comp(5, 1, Outcome::Aborted, 900),
        ];
        let r = RunReport::from_completions(&comps);
        assert_eq!(r.total, 5);
        assert_eq!(r.finished, 2);
        assert!((r.finish_rate() - 0.4).abs() < 1e-12);
        assert_eq!(r.per_app[&0], (1, 2));
        assert_eq!(r.per_app[&1], (1, 3));
        assert_eq!(r.timed_out, 1);
        assert_eq!(r.aborted, 1);
        // Single model → one per-model entry matching the aggregates,
        // shown in Display too (the breakdown is never hidden).
        assert_eq!(r.per_model.len(), 1);
        assert_eq!(r.per_model[&0].finished, 2);
        assert_eq!(r.per_model[&0].total, 5);
        assert!(format!("{r}").contains("models=["), "{r}");
    }

    #[test]
    fn serviced_outcomes_feed_latency_and_batch_size() {
        // Pin which outcomes feed each summary: Finished + Late (serviced)
        // drive latency and mean_batch_size; TimedOut/Aborted only counts.
        let mk = |id, outcome, at, batch_size| Completion {
            request: Request::new(id, AppId(0), 0, 1_000_000, 5.0),
            outcome,
            at,
            batch_size,
            worker: Some(0),
            best_effort: false,
        };
        let comps = vec![
            mk(1, Outcome::Finished, 100_000, 2),
            mk(2, Outcome::Late, 2_000_000, 4),
            mk(3, Outcome::TimedOut, 500, 0),
            mk(4, Outcome::Aborted, 900, 0),
        ];
        let r = RunReport::from_completions(&comps);
        assert_eq!((r.finished, r.late, r.timed_out, r.aborted), (1, 1, 1, 1));
        // Two serviced requests → two latency samples; the shed pair's
        // zero batch sizes must not drag the mean down.
        assert_eq!(r.latency.count, 2);
        assert!((r.mean_batch_size - 3.0).abs() < 1e-12, "{}", r.mean_batch_size);
        // The per-model summary sees the same serviced set.
        assert_eq!(r.per_model[&0].latency.count, 2);
        assert_eq!(r.per_model[&0].total, 4);
        assert_eq!(r.per_model[&0].finished, 1);
    }

    #[test]
    fn per_model_breakdown() {
        let comps = vec![
            comp_model(1, 0, Outcome::Finished, 100),
            comp_model(2, 0, Outcome::Finished, 200),
            comp_model(3, 1, Outcome::Late, 2_000_000),
            comp_model(4, 1, Outcome::Finished, 400),
            comp_model(5, 1, Outcome::TimedOut, 500),
        ];
        let r = RunReport::from_completions(&comps);
        assert_eq!(r.per_model.len(), 2);
        assert!((r.per_model[&0].finish_rate() - 1.0).abs() < 1e-12);
        assert!((r.per_model[&1].finish_rate() - 1.0 / 3.0).abs() < 1e-12);
        // Latency summaries cover completed requests only (2 for model 1).
        assert!(r.per_model[&1].latency.p99 > 0.0);
        let shown = format!("{r}");
        assert!(shown.contains("models=["), "{shown}");
        assert!(shown.contains("m0=1.00"), "{shown}");
    }

    #[test]
    fn best_effort_stays_out_of_slo_tallies() {
        // Two SLO-lane completions plus two best-effort ones (one served
        // on time, one late): the finish rate sees only the SLO lane, the
        // late executions of *both* lanes count as wasted work.
        let be = |id, outcome, at, batch_size| Completion {
            request: Request::new(id, AppId(0), 0, 1_000_000, 5.0),
            outcome,
            at,
            batch_size,
            worker: Some(0),
            best_effort: true,
        };
        let comps = vec![
            comp(1, 0, Outcome::Finished, 100),
            comp(2, 0, Outcome::Late, 2_000_000),
            be(3, Outcome::Finished, 900, 2),
            be(4, Outcome::Late, 3_000_000, 2),
        ];
        let r = RunReport::from_completions(&comps);
        assert_eq!(r.total, 4);
        assert_eq!(r.best_effort, 2);
        assert_eq!((r.finished, r.late), (1, 1));
        assert!((r.finish_rate() - 0.5).abs() < 1e-12, "{}", r.finish_rate());
        // One late SLO request + one late best-effort request, 5 ms each.
        assert!((r.wasted_ms - 10.0).abs() < 1e-12, "{}", r.wasted_ms);
        // Latency/batch summaries stay SLO-lane-only.
        assert_eq!(r.latency.count, 2);
        assert_eq!(r.per_app[&0], (1, 2));
        let shown = format!("{r}");
        assert!(shown.contains("be=2"), "{shown}");
        assert!(shown.contains("wasted=10ms"), "{shown}");
    }

    #[test]
    fn empty_report() {
        let r = RunReport::from_completions(&[]);
        assert_eq!(r.finish_rate(), 0.0);
        assert_eq!(r.total, 0);
        assert!(r.per_worker.is_empty());
        assert!(r.per_model.is_empty());
    }

    #[test]
    fn worker_stats_become_utilizations() {
        let stats = vec![
            WorkerStats {
                worker: 0,
                batches: 10,
                busy_us: 500,
            },
            WorkerStats {
                worker: 1,
                batches: 4,
                busy_us: 250,
            },
        ];
        let r = RunReport::from_completions(&[]).with_worker_stats(&stats, 1_000);
        assert_eq!(r.per_worker.len(), 2);
        assert!((r.per_worker[0].utilization - 0.5).abs() < 1e-12);
        assert!((r.per_worker[1].utilization - 0.25).abs() < 1e-12);
        let shown = format!("{r}");
        assert!(shown.contains("w0=0.50/10b"), "{shown}");
    }
}
