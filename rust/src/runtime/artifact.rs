//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the rust runtime (request path).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Model hyperparameters recorded in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub classes: usize,
    pub max_depth: usize,
}

/// One compiled variant: (depth, batch) → HLO file.
#[derive(Debug, Clone)]
pub struct Variant {
    pub depth: usize,
    pub batch: usize,
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub batch_sizes: Vec<usize>,
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading manifest in {dir:?}: {e} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let cfg = v.get("config");
        let need = |k: &str| -> anyhow::Result<usize> {
            cfg.get(k)
                .as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing config.{k}"))
        };
        let model = ModelInfo {
            vocab: need("vocab")?,
            seq: need("seq")?,
            d_model: need("d_model")?,
            classes: need("classes")?,
            max_depth: need("max_depth")?,
        };
        let batch_sizes = v
            .get("batch_sizes")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing batch_sizes"))?
            .iter()
            .filter_map(|x| x.as_u64().map(|b| b as usize))
            .collect();
        let variants = v
            .get("variants")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing variants"))?
            .iter()
            .map(|e| {
                Ok(Variant {
                    depth: e
                        .get("depth")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("variant depth"))?
                        as usize,
                    batch: e
                        .get("batch")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("variant batch"))?
                        as usize,
                    path: dir.join(
                        e.get("path")
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("variant path"))?,
                    ),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest {
            model,
            batch_sizes,
            variants,
        })
    }

    /// Variant lookup table keyed by (depth, batch).
    pub fn index(&self) -> BTreeMap<(usize, usize), &Variant> {
        self.variants
            .iter()
            .map(|v| ((v.depth, v.batch), v))
            .collect()
    }

    /// Smallest supported batch size ≥ n (None if n exceeds the max).
    pub fn batch_for(&self, n: usize) -> Option<usize> {
        self.batch_sizes.iter().copied().filter(|&b| b >= n).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        let manifest = r#"{
  "model": "early-exit-transformer",
  "config": {"vocab": 128, "seq": 16, "d_model": 64, "ffn": 128,
             "heads": 4, "classes": 16, "max_depth": 2, "seed": 0},
  "batch_sizes": [1, 2, 4],
  "variants": [
    {"depth": 1, "batch": 1, "path": "model_d1_b1.hlo.txt", "bytes": 10},
    {"depth": 2, "batch": 4, "path": "model_d2_b4.hlo.txt", "bytes": 10}
  ]
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("orloj_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.seq, 16);
        assert_eq!(m.model.max_depth, 2);
        assert_eq!(m.batch_sizes, vec![1, 2, 4]);
        assert_eq!(m.variants.len(), 2);
        let idx = m.index();
        assert!(idx.contains_key(&(2, 4)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_for_rounds_up() {
        let dir = std::env::temp_dir().join("orloj_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch_for(1), Some(1));
        assert_eq!(m.batch_for(3), Some(4));
        assert_eq!(m.batch_for(4), Some(4));
        assert_eq!(m.batch_for(5), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let dir = std::env::temp_dir().join("orloj_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "err={err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
