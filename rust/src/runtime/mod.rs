//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! request path. Python never runs here — the rust binary is self-contained
//! once `make artifacts` has produced the HLO files.
//!
//! Wiring (per /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod artifact;
pub mod executor;

use artifact::Manifest;
use std::collections::BTreeMap;
use std::path::Path;

/// A loaded model: one compiled PJRT executable per (depth, batch) variant.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla crate's PJRT wrappers hold raw pointers and therefore do
// not derive Send, but the PJRT C API is documented thread-compatible and
// Orloj moves the runtime onto exactly one worker thread (single-GPU
// semantics, §3.1) — it is never used from two threads concurrently.
unsafe impl Send for ModelRuntime {}
unsafe impl Sync for ModelRuntime {}

impl ModelRuntime {
    /// Load and compile every variant in the artifact directory. Compiling
    /// happens once at startup (Clockwork-style consolidation: no compile
    /// jitter on the request path).
    pub fn load(dir: &Path) -> anyhow::Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for v in &manifest.variants {
            let proto = xla::HloModuleProto::from_text_file(
                v.path
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path {:?}", v.path))?,
            )
            .map_err(|e| anyhow::anyhow!("parse {:?}: {e:?}", v.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {:?}: {e:?}", v.path))?;
            executables.insert((v.depth, v.batch), exe);
        }
        Ok(ModelRuntime {
            manifest,
            client,
            executables,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn variant_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute a (depth, batch) variant on `tokens` (row-major
    /// batch×seq i32). Returns the logits (batch × classes, f32).
    pub fn execute(
        &self,
        depth: usize,
        batch: usize,
        tokens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let seq = self.manifest.model.seq;
        anyhow::ensure!(
            tokens.len() == batch * seq,
            "tokens len {} != batch {batch} × seq {seq}",
            tokens.len()
        );
        let exe = self
            .executables
            .get(&(depth, batch))
            .ok_or_else(|| anyhow::anyhow!("no variant (depth={depth}, batch={batch})"))?;
        let input = xla::Literal::vec1(tokens).reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple of logits.
        let logits = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        logits
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }
}
