//! PJRT-backed worker: the real-execution end of the serving stack.
//!
//! Requests carry the early-exit depth they need (`Request::variant`); a
//! batch pads to the next supported batch size and runs at the max depth of
//! its members — the real analogue of Eq. 4's `l = max_r l_r` padding
//! semantics.

use super::ModelRuntime;
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId, Request};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::serve::Placement;
use crate::sim::worker::Worker;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// One serving replica: a scheduler paired with its PJRT executor.
pub type PjrtReplica = (Box<dyn Scheduler>, PjrtWorker);

/// One placed replica: a scheduler paired with a multi-model executor.
pub type PlacedReplica = (Box<dyn Scheduler>, MultiModelPjrtWorker);

/// Build the `(scheduler, PJRT worker)` replica list for
/// `Server::cluster`: one scheduler instance per runtime handle
/// (decorrelated per-replica seeds; replica 0 keeps `seed`, matching
/// `serve::Cluster::build`), each seeded with the calibrated per-depth
/// solo latencies (app d-1 ↔ early-exit depth d). Callers must pass one
/// `ModelRuntime` per replica — the PJRT client is thread-compatible, not
/// thread-safe, and each replica executes on its own thread.
pub fn pjrt_replicas(
    system: &str,
    cfg: &SchedulerConfig,
    seed: u64,
    calib: &[(usize, f64)],
    runtimes: &[Arc<ModelRuntime>],
) -> Option<Vec<PjrtReplica>> {
    let mut replicas = Vec::with_capacity(runtimes.len());
    for (w, rt) in runtimes.iter().enumerate() {
        let mut sched =
            crate::baselines::by_name(system, cfg.clone(), seed ^ ((w as u64) << 24))?;
        for (depth, ms) in calib {
            sched.seed_app_profile(
                ModelId::DEFAULT,
                AppId(*depth as u32 - 1),
                &Histogram::constant(*ms),
                100,
            );
        }
        replicas.push((sched, PjrtWorker::new(rt.clone())));
    }
    Some(replicas)
}

/// Build the placed replica list for a multi-model `Server`: one
/// scheduler per worker, and one loaded `ModelRuntime` per *hosted model*
/// per worker (each concurrent worker thread needs its own PJRT client —
/// thread-compatible, not thread-safe — and each hosted model its own
/// compiled executables, mirroring per-model GPU memory in a production
/// fleet). `reuse` is installed into the first hosted slot instead of
/// reloading from disk (callers typically have a calibration runtime in
/// hand). Every hosted model's scheduler profile is seeded from the
/// shared per-depth calibration. With `elastic` set, every worker keeps
/// the artifact directory for *lazy* runtime loads — an elastic
/// `LoadModel` dispatch loads the runtime on the worker's own thread at
/// placement time — every scheduler is seeded for every model (any
/// replica may acquire any model at runtime), and unloads release the
/// runtime. Returns None for an unknown system; panics on an
/// unconstrained placement (it names no models — parse one) or if
/// artifacts fail to load (demo path).
#[allow(clippy::too_many_arguments)]
pub fn pjrt_placed_replicas(
    system: &str,
    cfg: &SchedulerConfig,
    seed: u64,
    calib: &[(usize, f64)],
    dir: &Path,
    placement: &Placement,
    mut reuse: Option<Arc<ModelRuntime>>,
    elastic: bool,
) -> Option<Vec<PlacedReplica>> {
    let all_models = placement.models();
    assert!(
        !all_models.is_empty(),
        "pjrt_placed_replicas needs an explicit placement (Placement::parse); \
         an unconstrained placement names no models to load"
    );
    let mut replicas = Vec::with_capacity(placement.workers());
    for w in 0..placement.workers() {
        let mut sched =
            crate::baselines::by_name(system, cfg.clone(), seed ^ ((w as u64) << 24))?;
        let mut by_model = Vec::new();
        for &model in &all_models {
            let seeded = elastic || placement.hosts(w, model);
            if seeded {
                for (depth, ms) in calib {
                    sched.seed_app_profile(
                        model,
                        AppId(*depth as u32 - 1),
                        &Histogram::constant(*ms),
                        100,
                    );
                }
            }
            if !placement.hosts(w, model) {
                continue;
            }
            let rt = reuse
                .take()
                .unwrap_or_else(|| Arc::new(ModelRuntime::load(dir).expect("load artifacts")));
            by_model.push((model.0, PjrtWorker::new(rt)));
        }
        let mut worker = MultiModelPjrtWorker { by_model, artifacts: None };
        if elastic {
            worker.artifacts = Some(dir.to_path_buf());
        }
        replicas.push((sched, worker));
    }
    Some(replicas)
}

/// A worker hosting one PJRT runtime per model (cluster placement).
/// Batches are model-pure, so the batch's model picks the runtime. With
/// an artifact directory installed (elastic placement), a `LoadModel`
/// dispatch loads the model's runtime lazily on this worker's thread and
/// an unload releases it.
pub struct MultiModelPjrtWorker {
    by_model: Vec<(u32, PjrtWorker)>,
    /// Artifact directory for lazy loads (None = static hosting only).
    artifacts: Option<std::path::PathBuf>,
}

impl Worker for MultiModelPjrtWorker {
    fn execute(&mut self, batch: &[Request]) -> f64 {
        debug_assert!(
            batch.iter().all(|r| r.model == batch[0].model),
            "mixed-model batch reached a PJRT worker"
        );
        let model = batch.first().map_or(0, |r| r.model.0);
        match self.by_model.iter_mut().find(|(m, _)| *m == model) {
            Some((_, worker)) => worker.execute(batch),
            None => {
                // Routing guarantees hosted models only; fail loudly in
                // debug, measure nothing in release.
                debug_assert!(false, "batch for unhosted model {model}");
                0.0
            }
        }
    }

    fn load_model(&mut self, model: ModelId, cost_hint_ms: f64) -> f64 {
        if self.by_model.iter().any(|(m, _)| *m == model.0) {
            return 0.0; // already resident (e.g. re-install after a keep)
        }
        match &self.artifacts {
            Some(dir) => {
                // The real cold start: load the runtime on this worker's
                // own thread (the PJRT client is thread-compatible, not
                // thread-safe) and report the measured time.
                let t0 = Instant::now();
                let rt = Arc::new(ModelRuntime::load(dir).expect("load artifacts"));
                self.by_model.push((model.0, PjrtWorker::new(rt)));
                t0.elapsed().as_secs_f64() * 1000.0
            }
            None => cost_hint_ms,
        }
    }

    fn unload_model(&mut self, model: ModelId) {
        // Only elastic workers release runtimes: a static placement never
        // unloads, and keeping the runtime would make a later reload free
        // in a way the cold-start model doesn't account for.
        if self.artifacts.is_some() {
            self.by_model.retain(|(m, _)| *m != model.0);
        }
    }
}

pub struct PjrtWorker {
    runtime: Arc<ModelRuntime>,
}

impl PjrtWorker {
    pub fn new(runtime: Arc<ModelRuntime>) -> Self {
        PjrtWorker { runtime }
    }

    /// Deterministic synthetic tokens for a request (the serving path's
    /// payload stand-in; real deployments would carry user data here).
    fn tokens_for(&self, req: &Request, out: &mut Vec<i32>) {
        let seq = self.runtime.manifest.model.seq;
        let vocab = self.runtime.manifest.model.vocab as u64;
        let mut state = req.id.0.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        for _ in 0..seq {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            out.push((state % vocab) as i32);
        }
    }

    /// Measure the solo (bs=1) execution latency per depth — startup
    /// calibration used to seed profilers and fit the batch cost model.
    pub fn calibrate(&mut self, reps: usize) -> Vec<(usize, f64)> {
        let max_depth = self.runtime.manifest.model.max_depth;
        let mut out = Vec::new();
        for depth in 1..=max_depth {
            let req = Request::new(depth as u64, crate::core::request::AppId(0), 0, 1, 1.0)
                .with_variant(depth as u32);
            // Warm up once, then time.
            let _ = self.run_batch(&[req.clone()]);
            let t0 = Instant::now();
            for _ in 0..reps.max(1) {
                let _ = self.run_batch(&[req.clone()]);
            }
            out.push((depth, t0.elapsed().as_secs_f64() * 1000.0 / reps.max(1) as f64));
        }
        out
    }

    fn run_batch(&self, batch: &[Request]) -> anyhow::Result<Vec<f32>> {
        let m = &self.runtime.manifest;
        let depth = batch
            .iter()
            .map(|r| (r.variant.max(1) as usize).min(m.model.max_depth))
            .max()
            .unwrap_or(1);
        let padded = m
            .batch_for(batch.len())
            .unwrap_or_else(|| *m.batch_sizes.iter().max().unwrap());
        let seq = m.model.seq;
        let mut tokens = Vec::with_capacity(padded * seq);
        for r in batch.iter().take(padded) {
            self.tokens_for(r, &mut tokens);
        }
        // Pad with zero rows up to the variant's batch size.
        tokens.resize(padded * seq, 0);
        self.runtime.execute(depth, padded, &tokens)
    }
}

impl Worker for PjrtWorker {
    fn execute(&mut self, batch: &[Request]) -> f64 {
        let t0 = Instant::now();
        if let Err(e) = self.run_batch(batch) {
            // Surface runtime failures loudly; a failed batch still took
            // the measured time.
            crate::util::logging::log(
                crate::util::logging::Level::Error,
                "pjrt",
                format_args!("batch execution failed: {e}"),
            );
        }
        t0.elapsed().as_secs_f64() * 1000.0
    }
}
