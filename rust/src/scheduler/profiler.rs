//! Online per-(model, application) execution-time profiler (paper §3.2
//! "Per-Application Tracking" + "Long-Term Feedback Loop").
//!
//! Finished requests are *sampled* and their solo execution times
//! accumulated per `(model, app)` traffic class over a sliding window; the
//! scheduler's estimator picks up snapshots periodically, off the critical
//! path. Keying by `(model, app)` lets one scheduler replica serve several
//! co-located models without cross-contaminating their distributions. The
//! window resets wholesale every so often to adapt to input drift.

use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId};
use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::util::rng::Rng;

#[derive(Debug)]
struct AppWindow {
    samples: VecDeque<f64>,
    /// Total requests observed (not just sampled) — used as the mixture
    /// weight so the model-wide distribution reflects traffic shares.
    observed: u64,
}

/// Sliding-window per-(model, app) execution-time tracker.
#[derive(Debug)]
pub struct OnlineProfiler {
    window: usize,
    sample_prob: f64,
    bins: usize,
    apps: BTreeMap<(ModelId, AppId), AppWindow>,
    rng: Rng,
    version: u64,
}

/// A published snapshot: per-(model, app) histograms with traffic weights.
#[derive(Debug, Clone)]
pub struct ProfileSnapshot {
    pub apps: Vec<(ModelId, AppId, Histogram, f64)>,
    /// Monotonic version; consumers use it to detect staleness.
    pub version: u64,
}

impl ProfileSnapshot {
    pub fn empty() -> Self {
        ProfileSnapshot {
            apps: Vec::new(),
            version: 0,
        }
    }

    pub fn histogram_for(&self, model: ModelId, app: AppId) -> Option<&Histogram> {
        self.apps
            .iter()
            .find(|(m, a, _, _)| *m == model && *a == app)
            .map(|(_, _, h, _)| h)
    }

    /// One model's traffic mixture over its apps, weighted by traffic
    /// (§4.3: "always use all execution time distributions associated with
    /// the model" — *that* model's, not the cluster's).
    pub fn mixture(&self, model: ModelId, bins: usize) -> Option<Histogram> {
        let parts: Vec<(&Histogram, f64)> = self
            .apps
            .iter()
            .filter(|(m, _, _, _)| *m == model)
            .map(|(_, _, h, w)| (h, w.max(1e-9)))
            .collect();
        if parts.is_empty() {
            return None;
        }
        Some(Histogram::mixture(&parts, bins))
    }

    /// All models present in the snapshot, with their mixtures.
    pub fn mixtures(&self, bins: usize) -> Vec<(ModelId, Histogram)> {
        let mut models: Vec<ModelId> = self.apps.iter().map(|(m, _, _, _)| *m).collect();
        models.sort_unstable();
        models.dedup();
        models
            .into_iter()
            .filter_map(|m| self.mixture(m, bins).map(|h| (m, h)))
            .collect()
    }
}

impl OnlineProfiler {
    pub fn new(window: usize, sample_prob: f64, bins: usize, seed: u64) -> Self {
        assert!(window > 0 && (0.0..=1.0).contains(&sample_prob) && sample_prob > 0.0);
        OnlineProfiler {
            window,
            sample_prob,
            bins,
            apps: BTreeMap::new(),
            rng: Rng::new(seed),
            version: 0,
        }
    }

    /// Seed a (model, app) class with an a-priori distribution (the paper
    /// assumes historical data exists when SLOs are configured;
    /// experiments seed from the workload generator the way a production
    /// deployment would seed from the previous window).
    pub fn seed(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        let w = self
            .apps
            .entry((model, app))
            .or_insert_with(|| AppWindow {
                samples: VecDeque::new(),
                observed: 0,
            });
        // Materialize the histogram as quantile samples so later real
        // samples blend in smoothly.
        let n = self.window.min(256);
        for i in 0..n {
            let q = (i as f64 + 0.5) / n as f64;
            w.samples.push_back(hist.quantile(q));
        }
        w.observed += weight;
        self.version += 1;
    }

    /// Record a finished request's solo execution time.
    pub fn record(&mut self, model: ModelId, app: AppId, solo_exec_ms: f64) {
        let sampled = self.sample_prob >= 1.0 || self.rng.chance(self.sample_prob);
        let w = self
            .apps
            .entry((model, app))
            .or_insert_with(|| AppWindow {
                samples: VecDeque::new(),
                observed: 0,
            });
        w.observed += 1;
        if sampled {
            if w.samples.len() == self.window {
                w.samples.pop_front();
            }
            w.samples.push_back(solo_exec_ms);
            self.version += 1;
        }
    }

    /// Forget everything (drift adaptation; paper: "resets its profiling
    /// memory every once a while").
    pub fn reset(&mut self) {
        self.apps.clear();
        self.version += 1;
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Publish the current snapshot.
    pub fn snapshot(&self) -> ProfileSnapshot {
        let apps = self
            .apps
            .iter()
            .filter(|(_, w)| !w.samples.is_empty())
            .map(|((model, app), w)| {
                let v: Vec<f64> = w.samples.iter().copied().collect();
                (
                    *model,
                    *app,
                    Histogram::from_samples(&v, self.bins),
                    w.observed as f64,
                )
            })
            .collect();
        ProfileSnapshot {
            apps,
            version: self.version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M0: ModelId = ModelId(0);

    #[test]
    fn records_and_snapshots() {
        let mut p = OnlineProfiler::new(100, 1.0, 16, 1);
        for i in 0..50 {
            p.record(M0, AppId(0), 10.0 + (i % 5) as f64);
        }
        let s = p.snapshot();
        assert_eq!(s.apps.len(), 1);
        let h = s.histogram_for(M0, AppId(0)).unwrap();
        assert!((h.mean() - 12.0).abs() < 1.0);
    }

    #[test]
    fn window_evicts_old_samples() {
        let mut p = OnlineProfiler::new(10, 1.0, 8, 2);
        for _ in 0..50 {
            p.record(M0, AppId(0), 100.0);
        }
        for _ in 0..10 {
            p.record(M0, AppId(0), 1.0);
        }
        let h = p.snapshot();
        let hist = h.histogram_for(M0, AppId(0)).unwrap();
        assert!(hist.mean() < 2.0, "old samples must be gone: {}", hist.mean());
    }

    #[test]
    fn per_app_isolation_and_weights() {
        let mut p = OnlineProfiler::new(100, 1.0, 16, 3);
        for _ in 0..30 {
            p.record(M0, AppId(1), 5.0);
        }
        for _ in 0..10 {
            p.record(M0, AppId(2), 50.0);
        }
        let s = p.snapshot();
        assert_eq!(s.apps.len(), 2);
        let (_, _, _, w1) = s
            .apps
            .iter()
            .find(|(_, a, _, _)| *a == AppId(1))
            .unwrap();
        let (_, _, _, w2) = s
            .apps
            .iter()
            .find(|(_, a, _, _)| *a == AppId(2))
            .unwrap();
        assert_eq!(*w1, 30.0);
        assert_eq!(*w2, 10.0);
        // Mixture mean weighted 3:1 → (5*30 + 50*10)/40 = 16.25
        let mix = s.mixture(M0, 64).unwrap();
        assert!((mix.mean() - 16.25).abs() < 1.5, "mix mean {}", mix.mean());
    }

    #[test]
    fn models_do_not_cross_contaminate() {
        let mut p = OnlineProfiler::new(100, 1.0, 16, 9);
        for _ in 0..40 {
            p.record(ModelId(0), AppId(0), 5.0);
            p.record(ModelId(1), AppId(0), 80.0);
        }
        let s = p.snapshot();
        assert_eq!(s.apps.len(), 2);
        let h0 = s.histogram_for(ModelId(0), AppId(0)).unwrap();
        let h1 = s.histogram_for(ModelId(1), AppId(0)).unwrap();
        assert!(h0.mean() < 10.0, "model 0 mean {}", h0.mean());
        assert!(h1.mean() > 60.0, "model 1 mean {}", h1.mean());
        // Per-model mixtures stay separated too.
        let m0 = s.mixture(ModelId(0), 32).unwrap();
        let m1 = s.mixture(ModelId(1), 32).unwrap();
        assert!(m0.mean() < 10.0 && m1.mean() > 60.0);
        assert_eq!(s.mixtures(32).len(), 2);
    }

    #[test]
    fn sampling_probability_reduces_rate() {
        let mut p = OnlineProfiler::new(100_000, 0.1, 16, 4);
        for _ in 0..10_000 {
            p.record(M0, AppId(0), 1.0);
        }
        let s = p.snapshot();
        let (_, _, h, w) = &s.apps[0];
        assert_eq!(*w, 10_000.0); // observed counts everything
        // but samples ≈ 1000
        let _ = h;
        // (can't read sample count from histogram; version is a proxy)
        assert!(p.version() < 2_000, "sampled too much: {}", p.version());
        assert!(p.version() > 500, "sampled too little: {}", p.version());
    }

    #[test]
    fn seed_then_reset() {
        let mut p = OnlineProfiler::new(512, 1.0, 16, 5);
        let h = Histogram::from_weights(10.0, 1.0, &[1.0, 1.0]);
        p.seed(M0, AppId(0), &h, 100);
        let s = p.snapshot();
        assert!((s.histogram_for(M0, AppId(0)).unwrap().mean() - 11.0).abs() < 0.3);
        p.reset();
        assert!(p.snapshot().apps.is_empty());
    }

    #[test]
    fn empty_snapshot_mixture_none() {
        let p = OnlineProfiler::new(10, 1.0, 8, 6);
        assert!(p.snapshot().mixture(M0, 8).is_none());
        assert!(p.snapshot().mixtures(8).is_empty());
    }
}
