//! Batch latency estimation with the §4.3 precomputation trick.
//!
//! The priority score needs the batch latency distribution `L_B`, but the
//! batch is formed *after* scores are computed. Orloj breaks the cycle by
//! assuming the queue contains requests from all applications the model
//! serves: for a request of `(model, app)` considered at batch size `k`,
//! `L_B` is the affine image (Eq. 9) of the max of {1 draw from the app's
//! distribution, k−1 draws from *that model's* traffic mixture}. This
//! depends only on (model, app, k) — a small table precomputed off the
//! critical path and refreshed when the profiler publishes a new snapshot.
//! Batches never mix models, so each model's table uses its own mixture
//! and its own batch cost model.

use super::profiler::ProfileSnapshot;
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::orderstats;
use crate::core::priority::ScoreTemplate;
use crate::core::request::{AppId, ModelId};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::Arc;

/// Precomputed batch latency info for one (model, app, batch-size) triple.
#[derive(Debug, Clone)]
pub struct BatchLatency {
    /// Distribution of the batch execution time (ms).
    pub dist: Histogram,
    /// Coarsened copy used for the priority-score schedule (fewer
    /// milestones; see SchedulerConfig::score_bins).
    pub score_dist: Histogram,
    /// Deadline-relative score-schedule template over `score_dist` (§Perf):
    /// `on_arrival` / base resets instantiate it in O(1) instead of
    /// re-deriving the per-bin exponential math per request.
    pub template: Arc<ScoreTemplate>,
    /// E[L_B] (Eq. 5).
    pub mean: f64,
    /// Quantile used for the Algorithm-1 feasibility check.
    pub feasibility_ms: f64,
}

impl BatchLatency {
    /// The telemetry-facing prediction: mean exec time plus the p10/p90
    /// band of the estimated distribution (Eq. 1–2), against which the
    /// calibration report measures realized batch times.
    pub fn prediction(&self) -> crate::scheduler::BatchPrediction {
        crate::scheduler::BatchPrediction {
            ms: self.mean,
            lo_ms: self.dist.quantile(0.1),
            hi_ms: self.dist.quantile(0.9),
        }
    }
}

/// Estimator over the current profile snapshot.
#[derive(Debug)]
pub struct Estimator {
    cost: BatchCostModel,
    /// Per-model cost overrides (heterogeneous co-located models).
    model_costs: Vec<(u32, BatchCostModel)>,
    bins: usize,
    score_bins: usize,
    feasibility_quantile: f64,
    snapshot: ProfileSnapshot,
    /// Per-model traffic mixtures derived from the snapshot.
    mixtures: Vec<(ModelId, Histogram)>,
    cache: HashMap<(u32, u32, usize), BatchLatency>,
    /// Fallback solo execution time (ms) before any profile exists.
    cold_start_ms: f64,
    /// Score parameter `b` used to precompute the schedule templates
    /// (matches `SchedulerConfig::b`).
    priority_b: f64,
    /// One-shot per-model warm-up surcharge (ms), charged into the
    /// feasibility latency after an elastic model install until the
    /// model's first batch completes (DESIGN.md §8). Kept outside the
    /// `BatchLatency` cache so installing/clearing it never invalidates
    /// the precomputed templates.
    warmup: Vec<(u32, f64)>,
}

impl Estimator {
    pub fn new(cost: BatchCostModel, bins: usize, feasibility_quantile: f64) -> Self {
        Estimator::with_score_bins(cost, bins, bins.min(16), feasibility_quantile)
    }

    pub fn with_score_bins(
        cost: BatchCostModel,
        bins: usize,
        score_bins: usize,
        feasibility_quantile: f64,
    ) -> Self {
        Estimator {
            cost,
            model_costs: Vec::new(),
            bins,
            score_bins,
            feasibility_quantile,
            snapshot: ProfileSnapshot::empty(),
            mixtures: Vec::new(),
            cache: HashMap::new(),
            cold_start_ms: 10.0,
            priority_b: 1e-4,
            warmup: Vec::new(),
        }
    }

    pub fn cost_model(&self) -> BatchCostModel {
        self.cost
    }

    /// Set the score parameter `b` the schedule templates are built for
    /// (invalidates the cache). Defaults to the paper's 1e-4 per ms.
    pub fn set_priority_b(&mut self, b: f64) {
        assert!(b > 0.0);
        if b != self.priority_b {
            self.priority_b = b;
            self.cache.clear();
        }
    }

    /// Install per-model cost models (invalidates the cache).
    pub fn set_model_costs(&mut self, costs: &[(u32, BatchCostModel)]) {
        self.model_costs = costs.to_vec();
        self.cache.clear();
    }

    /// Cost model for one model (falls back to the shared default).
    pub fn cost_for(&self, model: ModelId) -> BatchCostModel {
        cost_for_in(&self.model_costs, self.cost, model)
    }

    /// Install a fresh profiler snapshot (invalidates the cache).
    pub fn refresh(&mut self, snapshot: ProfileSnapshot) {
        self.mixtures = snapshot.mixtures(self.bins);
        self.snapshot = snapshot;
        self.cache.clear();
    }

    pub fn snapshot_version(&self) -> u64 {
        self.snapshot.version
    }

    /// Batch latency for a request of `(model, app)` at batch size `k`
    /// (cached). Single map lookup on both hit and miss: the `entry` API
    /// plus field-level split borrows replaces the historical
    /// `contains_key` + `insert` + `get` triple.
    pub fn batch_latency(&mut self, model: ModelId, app: AppId, k: usize) -> &BatchLatency {
        let key = (model.0, app.0, k);
        let Estimator {
            cache,
            snapshot,
            mixtures,
            cost,
            model_costs,
            bins,
            score_bins,
            feasibility_quantile,
            cold_start_ms,
            priority_b,
        } = self;
        match cache.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => v.insert(compute_batch_latency(
                snapshot,
                mixtures,
                cost_for_in(model_costs, *cost, model),
                *bins,
                *score_bins,
                *feasibility_quantile,
                *cold_start_ms,
                *priority_b,
                model,
                app,
                k,
            )),
        }
    }

    /// Feasibility latency (ms) for Algorithm 1 line 11, including any
    /// pending warm-up surcharge for the model (elastic installs).
    pub fn feasibility_ms(&mut self, model: ModelId, app: AppId, k: usize) -> f64 {
        let base = self.batch_latency(model, app, k).feasibility_ms;
        base + self.warmup_ms(model)
    }

    /// Charge a one-shot warm-up surcharge for `model` (an elastic
    /// install's cold-start cost): until [`Estimator::clear_warmup`] runs,
    /// the model's feasibility latency includes it, so the scheduler
    /// won't promise deadlines the warming replica cannot keep.
    pub fn set_warmup_ms(&mut self, model: ModelId, ms: f64) {
        self.clear_warmup(model);
        if ms > 0.0 {
            self.warmup.push((model.0, ms));
        }
    }

    /// Clear `model`'s warm-up surcharge (its first batch completed).
    pub fn clear_warmup(&mut self, model: ModelId) {
        self.warmup.retain(|(m, _)| *m != model.0);
    }

    /// Pending warm-up surcharge for `model` (0 when fully warm).
    pub fn warmup_ms(&self, model: ModelId) -> f64 {
        self.warmup
            .iter()
            .find(|(m, _)| *m == model.0)
            .map_or(0.0, |(_, w)| *w)
    }

    /// Whether any model currently carries a warm-up surcharge.
    pub fn has_warmup(&self) -> bool {
        !self.warmup.is_empty()
    }

    /// Mean solo execution time of `model`'s current traffic mixture, ms
    /// (the admission backlog estimate's per-request cost). Pure read of
    /// the precomputed mixture — no cache involvement; 10 ms cold-start
    /// placeholder when the model has no profile yet.
    pub fn model_mean_ms(&self, model: ModelId) -> f64 {
        self.mixtures
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(10.0, |(_, h)| h.mean())
    }
}

fn cost_for_in(
    model_costs: &[(u32, BatchCostModel)],
    default: BatchCostModel,
    model: ModelId,
) -> BatchCostModel {
    model_costs
        .iter()
        .find(|(m, _)| *m == model.0)
        .map_or(default, |(_, c)| *c)
}

/// The §4.3 precompute for one (model, app, k) triple — a free function so
/// `batch_latency` can run it inside the cache's vacant `entry` while
/// holding only field-level borrows.
#[allow(clippy::too_many_arguments)]
fn compute_batch_latency(
    snapshot: &ProfileSnapshot,
    mixtures: &[(ModelId, Histogram)],
    cost: BatchCostModel,
    bins: usize,
    score_bins: usize,
    feasibility_quantile: f64,
    cold_start_ms: f64,
    priority_b: f64,
    model: ModelId,
    app: AppId,
    k: usize,
) -> BatchLatency {
    assert!(k >= 1);
    let mixture_for = |m: ModelId| mixtures.iter().find(|(mm, _)| *mm == m).map(|(_, h)| h);
    let own = snapshot
        .histogram_for(model, app)
        .or_else(|| mixture_for(model))
        .cloned()
        .unwrap_or_else(|| Histogram::constant(cold_start_ms));
    let max_dist = if k == 1 {
        own
    } else {
        match mixture_for(model) {
            Some(mix) => orderstats::max_grouped(&[&own, mix], &[1, k - 1], bins),
            None => orderstats::max_iid(&own, k),
        }
    };
    let dist = max_dist.affine(cost.c1 * k as f64, cost.c0);
    let mean = dist.mean();
    let feasibility_ms = dist.quantile(feasibility_quantile);
    let score_dist = dist.coarsen(score_bins);
    let template = Arc::new(ScoreTemplate::new(priority_b, &score_dist));
    BatchLatency {
        dist,
        score_dist,
        template,
        mean,
        feasibility_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::profiler::OnlineProfiler;

    const M0: ModelId = ModelId(0);

    fn snapshot_two_apps() -> ProfileSnapshot {
        let mut p = OnlineProfiler::new(1000, 1.0, 32, 7);
        for i in 0..500 {
            p.record(M0, AppId(0), 4.0 + (i % 3) as f64); // short app: 4-6 ms
            p.record(M0, AppId(1), 40.0 + (i % 7) as f64); // long app: 40-46 ms
        }
        p.snapshot()
    }

    #[test]
    fn cold_start_fallback() {
        let mut e = Estimator::new(BatchCostModel::new(1.0, 0.5), 32, 0.5);
        let bl = e.batch_latency(M0, AppId(9), 4);
        assert!(bl.mean > 0.0);
        // constant 10ms → max = 10, latency = 1 + 0.5*4*10 = 21
        assert!((bl.mean - 21.0).abs() < 0.5, "mean={}", bl.mean);
    }

    #[test]
    fn own_distribution_at_k1() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let short = e.batch_latency(M0, AppId(0), 1).mean;
        let long = e.batch_latency(M0, AppId(1), 1).mean;
        assert!((short - 5.0).abs() < 1.0, "short={short}");
        assert!((long - 43.0).abs() < 2.0, "long={long}");
    }

    #[test]
    fn mixture_dominates_large_batches() {
        // At k≥2, even a short-app request inherits the long tail of the
        // traffic mixture (the straggler effect the paper schedules around).
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let k2_short = e.batch_latency(M0, AppId(0), 2).mean;
        // max(own_short, one mixture draw): mixture is 50/50 short/long →
        // ~50% chance the other draw is ~43ms → E[max] ≈ 0.5·5 + 0.5·43 ≈ 24
        // then ×k=2 → ≈ 48.
        assert!(k2_short > 30.0, "k2_short={k2_short}");
    }

    #[test]
    fn feasibility_quantile_monotone() {
        let mut lo = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.25);
        let mut hi = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.95);
        lo.refresh(snapshot_two_apps());
        hi.refresh(snapshot_two_apps());
        for k in [1usize, 2, 8] {
            assert!(
                hi.feasibility_ms(M0, AppId(0), k) >= lo.feasibility_ms(M0, AppId(0), k),
                "k={k}"
            );
        }
    }

    #[test]
    fn cache_survives_until_refresh() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 32, 0.5);
        e.refresh(snapshot_two_apps());
        let a = e.batch_latency(M0, AppId(0), 4).mean;
        let b = e.batch_latency(M0, AppId(0), 4).mean;
        assert_eq!(a, b);
        // Refresh with different data changes the estimate.
        let mut p = OnlineProfiler::new(100, 1.0, 32, 8);
        for _ in 0..100 {
            p.record(M0, AppId(0), 100.0);
        }
        e.refresh(p.snapshot());
        let c = e.batch_latency(M0, AppId(0), 4).mean;
        assert!(c > a * 2.0, "estimate should jump: {a} -> {c}");
    }

    #[test]
    fn unknown_app_uses_mixture() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let unk = e.batch_latency(M0, AppId(42), 1).mean;
        // mixture mean ≈ (5+43)/2 = 24
        assert!((unk - 24.0).abs() < 3.0, "unk={unk}");
    }

    #[test]
    fn cached_entries_share_one_template() {
        // The whole point of the template: every arrival of the same
        // (model, app, k) class instantiates the *same* Arc until the next
        // snapshot refresh.
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 32, 0.5);
        e.refresh(snapshot_two_apps());
        let t1 = Arc::clone(&e.batch_latency(M0, AppId(0), 4).template);
        let t2 = Arc::clone(&e.batch_latency(M0, AppId(0), 4).template);
        assert!(Arc::ptr_eq(&t1, &t2));
        assert!(t1.num_segments() >= 2);
        // Different class → different template.
        let t3 = Arc::clone(&e.batch_latency(M0, AppId(1), 4).template);
        assert!(!Arc::ptr_eq(&t1, &t3));
        // Refresh rebuilds.
        e.refresh(snapshot_two_apps());
        let t4 = Arc::clone(&e.batch_latency(M0, AppId(0), 4).template);
        assert!(!Arc::ptr_eq(&t1, &t4));
    }

    #[test]
    fn priority_b_change_invalidates_cache() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 32, 0.5);
        e.refresh(snapshot_two_apps());
        let t1 = Arc::clone(&e.batch_latency(M0, AppId(0), 2).template);
        e.set_priority_b(1e-3);
        let t2 = Arc::clone(&e.batch_latency(M0, AppId(0), 2).template);
        assert!(!Arc::ptr_eq(&t1, &t2));
        // Same b again is a no-op (cache kept).
        e.set_priority_b(1e-3);
        let t3 = Arc::clone(&e.batch_latency(M0, AppId(0), 2).template);
        assert!(Arc::ptr_eq(&t2, &t3));
    }

    #[test]
    fn warmup_surcharge_is_one_shot_and_per_model() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let base = e.feasibility_ms(M0, AppId(0), 2);
        assert!(!e.has_warmup());
        e.set_warmup_ms(M0, 200.0);
        assert!(e.has_warmup());
        assert!(
            (e.feasibility_ms(M0, AppId(0), 2) - (base + 200.0)).abs() < 1e-9,
            "cold start charged into feasibility"
        );
        // Other models are untouched.
        let other = e.feasibility_ms(ModelId(7), AppId(0), 1);
        e.set_warmup_ms(ModelId(7), 50.0);
        assert!((e.feasibility_ms(ModelId(7), AppId(0), 1) - (other + 50.0)).abs() < 1e-9);
        assert!((e.feasibility_ms(M0, AppId(0), 2) - (base + 200.0)).abs() < 1e-9);
        // Re-set replaces, clear removes.
        e.set_warmup_ms(M0, 80.0);
        assert!((e.warmup_ms(M0) - 80.0).abs() < 1e-12);
        e.clear_warmup(M0);
        assert!((e.feasibility_ms(M0, AppId(0), 2) - base).abs() < 1e-12);
        // The template cache was never invalidated by warm-up churn.
        let t1 = Arc::clone(&e.batch_latency(M0, AppId(0), 2).template);
        e.set_warmup_ms(M0, 10.0);
        let t2 = Arc::clone(&e.batch_latency(M0, AppId(0), 2).template);
        assert!(Arc::ptr_eq(&t1, &t2));
    }

    #[test]
    fn co_located_models_use_their_own_mixture_and_cost() {
        let mut p = OnlineProfiler::new(1000, 1.0, 32, 11);
        for _ in 0..400 {
            p.record(ModelId(0), AppId(0), 5.0);
            p.record(ModelId(1), AppId(0), 50.0);
        }
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.set_model_costs(&[(1, BatchCostModel::new(0.0, 2.0))]);
        e.refresh(p.snapshot());
        // k=4 on model 0 stays near 4·5 = 20 ms (its own mixture; no
        // contamination from model 1's 50 ms requests).
        let m0 = e.batch_latency(ModelId(0), AppId(0), 4).mean;
        assert!(m0 < 30.0, "m0={m0}");
        // Model 1 pays its own cost model (c1=2): ≈ 2·4·50 = 400 ms.
        let m1 = e.batch_latency(ModelId(1), AppId(0), 4).mean;
        assert!(m1 > 300.0, "m1={m1}");
        assert_eq!(e.cost_for(ModelId(0)), BatchCostModel::new(0.0, 1.0));
        assert_eq!(e.cost_for(ModelId(1)), BatchCostModel::new(0.0, 2.0));
    }
}
