//! Batch latency estimation with the §4.3 precomputation trick.
//!
//! The priority score needs the batch latency distribution `L_B`, but the
//! batch is formed *after* scores are computed. Orloj breaks the cycle by
//! assuming the queue contains requests from all applications the model
//! serves: for a request of app `a` considered at batch size `k`, `L_B` is
//! the affine image (Eq. 9) of the max of {1 draw from app a's
//! distribution, k−1 draws from the model-wide traffic mixture}. This
//! depends only on (app, k) — a small table precomputed off the critical
//! path and refreshed when the profiler publishes a new snapshot.

use super::profiler::ProfileSnapshot;
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::orderstats;
use crate::core::request::AppId;
use std::collections::HashMap;

/// Precomputed batch latency info for one (app, batch-size) pair.
#[derive(Debug, Clone)]
pub struct BatchLatency {
    /// Distribution of the batch execution time (ms).
    pub dist: Histogram,
    /// Coarsened copy used for the priority-score schedule (fewer
    /// milestones; see SchedulerConfig::score_bins).
    pub score_dist: Histogram,
    /// E[L_B] (Eq. 5).
    pub mean: f64,
    /// Quantile used for the Algorithm-1 feasibility check.
    pub feasibility_ms: f64,
}

/// Estimator over the current profile snapshot.
#[derive(Debug)]
pub struct Estimator {
    model: BatchCostModel,
    bins: usize,
    score_bins: usize,
    feasibility_quantile: f64,
    snapshot: ProfileSnapshot,
    mixture: Option<Histogram>,
    cache: HashMap<(u32, usize), BatchLatency>,
    /// Fallback solo execution time (ms) before any profile exists.
    cold_start_ms: f64,
}

impl Estimator {
    pub fn new(model: BatchCostModel, bins: usize, feasibility_quantile: f64) -> Self {
        Estimator::with_score_bins(model, bins, bins.min(16), feasibility_quantile)
    }

    pub fn with_score_bins(
        model: BatchCostModel,
        bins: usize,
        score_bins: usize,
        feasibility_quantile: f64,
    ) -> Self {
        Estimator {
            model,
            bins,
            score_bins,
            feasibility_quantile,
            snapshot: ProfileSnapshot::empty(),
            mixture: None,
            cache: HashMap::new(),
            cold_start_ms: 10.0,
        }
    }

    pub fn cost_model(&self) -> BatchCostModel {
        self.model
    }

    /// Install a fresh profiler snapshot (invalidates the cache).
    pub fn refresh(&mut self, snapshot: ProfileSnapshot) {
        self.mixture = snapshot.mixture(self.bins);
        self.snapshot = snapshot;
        self.cache.clear();
    }

    pub fn snapshot_version(&self) -> u64 {
        self.snapshot.version
    }

    /// Batch latency for a request of `app` at batch size `k` (cached).
    pub fn batch_latency(&mut self, app: AppId, k: usize) -> &BatchLatency {
        let key = (app.0, k);
        if !self.cache.contains_key(&key) {
            let bl = self.compute(app, k);
            self.cache.insert(key, bl);
        }
        self.cache.get(&key).unwrap()
    }

    fn compute(&self, app: AppId, k: usize) -> BatchLatency {
        assert!(k >= 1);
        let own = self
            .snapshot
            .histogram_for(app)
            .cloned()
            .or_else(|| self.mixture.clone())
            .unwrap_or_else(|| Histogram::constant(self.cold_start_ms));
        let max_dist = if k == 1 {
            own
        } else {
            match &self.mixture {
                Some(mix) => orderstats::max_grouped(&[&own, mix], &[1, k - 1], self.bins),
                None => orderstats::max_iid(&own, k),
            }
        };
        let dist = max_dist.affine(self.model.c1 * k as f64, self.model.c0);
        let mean = dist.mean();
        let feasibility_ms = dist.quantile(self.feasibility_quantile);
        let score_dist = dist.coarsen(self.score_bins);
        BatchLatency {
            dist,
            score_dist,
            mean,
            feasibility_ms,
        }
    }

    /// Feasibility latency (ms) for Algorithm 1 line 11.
    pub fn feasibility_ms(&mut self, app: AppId, k: usize) -> f64 {
        self.batch_latency(app, k).feasibility_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::profiler::OnlineProfiler;

    fn snapshot_two_apps() -> ProfileSnapshot {
        let mut p = OnlineProfiler::new(1000, 1.0, 32, 7);
        for i in 0..500 {
            p.record(AppId(0), 4.0 + (i % 3) as f64); // short app: 4-6 ms
            p.record(AppId(1), 40.0 + (i % 7) as f64); // long app: 40-46 ms
        }
        p.snapshot()
    }

    #[test]
    fn cold_start_fallback() {
        let mut e = Estimator::new(BatchCostModel::new(1.0, 0.5), 32, 0.5);
        let bl = e.batch_latency(AppId(9), 4);
        assert!(bl.mean > 0.0);
        // constant 10ms → max = 10, latency = 1 + 0.5*4*10 = 21
        assert!((bl.mean - 21.0).abs() < 0.5, "mean={}", bl.mean);
    }

    #[test]
    fn own_distribution_at_k1() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let short = e.batch_latency(AppId(0), 1).mean;
        let long = e.batch_latency(AppId(1), 1).mean;
        assert!((short - 5.0).abs() < 1.0, "short={short}");
        assert!((long - 43.0).abs() < 2.0, "long={long}");
    }

    #[test]
    fn mixture_dominates_large_batches() {
        // At k≥2, even a short-app request inherits the long tail of the
        // traffic mixture (the straggler effect the paper schedules around).
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let k2_short = e.batch_latency(AppId(0), 2).mean;
        // max(own_short, one mixture draw): mixture is 50/50 short/long →
        // ~50% chance the other draw is ~43ms → E[max] ≈ 0.5·5 + 0.5·43 ≈ 24
        // then ×k=2 → ≈ 48.
        assert!(k2_short > 30.0, "k2_short={k2_short}");
    }

    #[test]
    fn feasibility_quantile_monotone() {
        let mut lo = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.25);
        let mut hi = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.95);
        lo.refresh(snapshot_two_apps());
        hi.refresh(snapshot_two_apps());
        for k in [1usize, 2, 8] {
            assert!(
                hi.feasibility_ms(AppId(0), k) >= lo.feasibility_ms(AppId(0), k),
                "k={k}"
            );
        }
    }

    #[test]
    fn cache_survives_until_refresh() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 32, 0.5);
        e.refresh(snapshot_two_apps());
        let a = e.batch_latency(AppId(0), 4).mean;
        let b = e.batch_latency(AppId(0), 4).mean;
        assert_eq!(a, b);
        // Refresh with different data changes the estimate.
        let mut p = OnlineProfiler::new(100, 1.0, 32, 8);
        for _ in 0..100 {
            p.record(AppId(0), 100.0);
        }
        e.refresh(p.snapshot());
        let c = e.batch_latency(AppId(0), 4).mean;
        assert!(c > a * 2.0, "estimate should jump: {a} -> {c}");
    }

    #[test]
    fn unknown_app_uses_mixture() {
        let mut e = Estimator::new(BatchCostModel::new(0.0, 1.0), 64, 0.5);
        e.refresh(snapshot_two_apps());
        let unk = e.batch_latency(AppId(42), 1).mean;
        // mixture mean ≈ (5+43)/2 = 24
        assert!((unk - 24.0).abs() < 3.0, "unk={unk}");
    }
}
