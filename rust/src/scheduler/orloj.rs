//! The Orloj scheduler — Algorithm 1 of the paper.
//!
//! Per supported batch size `bs` there is a queue `Q_bs` holding the
//! requests still *feasible* at that size (`t + EstBatchLatency(r, bs) ≤
//! D_r`). Each queue is a dynamic convex hull over the requests' (α, β)
//! priority points (§4.4) plus a Fibonacci heap tracking the earliest
//! deadline (§3.2). Because a batch executes exactly one model, the queue
//! set is *partitioned per hosted model* (cluster placement, DESIGN.md
//! §3): one [`ModelGroup`] of `|S|` queues per co-located model, with the
//! estimator/profiler tables keyed by `(model, app)` so the models never
//! cross-contaminate each other's distributions. One scheduler iteration:
//!
//! 1. reset the score base time if `b·t` is near overflow (lines 2–4);
//! 2. re-insert hull points whose milestone passed (lines 5–9);
//! 3. prune infeasible requests from each queue, marking requests timed
//!    out when they leave their last queue (lines 10–14);
//! 4. pick the candidate queue across all (model, bs) pairs — ordered by
//!    (earliest deadline, bs) descending, first with `|Q_bs| ≥ bs`
//!    (lines 15–21);
//! 5. pop the top-priority requests from the candidate queue (line 22).
//!
//! **Hot-path layout (§Perf, DESIGN.md §7).** Pending requests live in a
//! *generational slab*: hull point ids, Fibonacci-heap payloads and
//! milestone-heap payloads all carry the dense slab key, so none of the
//! per-decision steps hash anything. Score schedules are instantiated from
//! the estimator's shared per-`(model, app, bs)` [`ScoreTemplate`]s in
//! O(1). Candidate selection reads a persistent index of per-queue minimum
//! deadlines that is maintained eagerly at each queue mutation — the
//! historical allocate-and-sort of every `(model, bs)` pair per
//! `next_batch` is gone, and `wake_hint` answers from the same index in
//! O(1). Steady-state `next_batch` performs no heap allocation in the
//! scheduler-owned bookkeeping (see DESIGN.md §7 for the audit).

use super::estimator::Estimator;
use super::profiler::OnlineProfiler;
use super::{BatchPrediction, Scheduler, SchedulerConfig};
use crate::clock::{ms_to_us, us_to_ms, Micros};
use crate::core::histogram::Histogram;
use crate::core::priority::{ScoreContext, ScoreSchedule};
use crate::core::request::{AppId, ModelId, Outcome, Request};
use crate::ds::fibheap::{FibHeap, Handle};
use crate::ds::hull::point::Point;
use crate::ds::hull::DynamicHull;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Per-(request, batch-size) queue residency.
struct BsEntry {
    sched: ScoreSchedule,
    point: Point,
    fib: Handle,
}

/// A pending request with its per-queue state.
struct Entry {
    req: Request,
    /// Index of the request's [`ModelGroup`] in `groups`.
    group: usize,
    per_bs: Vec<Option<BsEntry>>,
    /// Next milestone (absolute µs) registered in the milestone heap; used
    /// to invalidate stale heap entries lazily.
    milestone: Option<Micros>,
}

/// Slab key: `(generation << 32) | slot`. The generation guards against
/// slot reuse: a stale key (e.g. a milestone registered by a dispatched
/// request whose slot now holds a newer one) simply fails to resolve.
#[inline]
fn slab_key(slot: u32, gen: u32) -> u64 {
    ((gen as u64) << 32) | slot as u64
}

struct SlotCell {
    gen: u32,
    entry: Option<Entry>,
}

/// Generational slab of pending entries — the dense, hash-free store
/// behind every per-decision lookup (hull point ids, fib-heap payloads and
/// milestone payloads are all slab keys).
#[derive(Default)]
struct EntrySlab {
    slots: Vec<SlotCell>,
    free: Vec<u32>,
    live: usize,
}

impl EntrySlab {
    /// The key the next [`EntrySlab::insert`] will return (so hull/fib
    /// state can be tagged before the entry itself is stored).
    fn next_key(&self) -> u64 {
        match self.free.last() {
            Some(&slot) => slab_key(slot, self.slots[slot as usize].gen),
            None => slab_key(self.slots.len() as u32, 0),
        }
    }

    fn insert(&mut self, entry: Entry) -> u64 {
        self.live += 1;
        match self.free.pop() {
            Some(slot) => {
                let cell = &mut self.slots[slot as usize];
                debug_assert!(cell.entry.is_none(), "free list pointed at a live slot");
                cell.entry = Some(entry);
                slab_key(slot, cell.gen)
            }
            None => {
                self.slots.push(SlotCell {
                    gen: 0,
                    entry: Some(entry),
                });
                slab_key((self.slots.len() - 1) as u32, 0)
            }
        }
    }

    fn get(&self, key: u64) -> Option<&Entry> {
        let cell = self.slots.get((key & 0xffff_ffff) as usize)?;
        if cell.gen != (key >> 32) as u32 {
            return None;
        }
        cell.entry.as_ref()
    }

    fn get_mut(&mut self, key: u64) -> Option<&mut Entry> {
        let cell = self.slots.get_mut((key & 0xffff_ffff) as usize)?;
        if cell.gen != (key >> 32) as u32 {
            return None;
        }
        cell.entry.as_mut()
    }

    /// Remove and return the entry; bumps the slot's generation so stale
    /// keys can never alias the next resident.
    fn remove(&mut self, key: u64) -> Option<Entry> {
        let slot = (key & 0xffff_ffff) as usize;
        let cell = self.slots.get_mut(slot)?;
        if cell.gen != (key >> 32) as u32 {
            return None;
        }
        let entry = cell.entry.take()?;
        cell.gen = cell.gen.wrapping_add(1);
        self.free.push(slot as u32);
        self.live -= 1;
        Some(entry)
    }

    fn len(&self) -> usize {
        self.live
    }

    fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Key of the live entry in `slot`, if any (for full scans like the
    /// Algorithm-1 base reset).
    fn key_at(&self, slot: usize) -> Option<u64> {
        let cell = &self.slots[slot];
        cell.entry.as_ref().map(|_| slab_key(slot as u32, cell.gen))
    }
}

/// One candidate-index entry: a queue's (min deadline, bs, group, queue).
type QueueKey = (Micros, usize, usize, usize);

/// Persistent Algorithm-1 line-16 candidate order: every non-empty queue's
/// `(D_Qbs, bs, gi, qi)`, iterated in descending tuple order (the `(gi,
/// qi)` tail keeps exact ties deterministic). Maintained eagerly whenever
/// a queue's earliest deadline changes, so steady-state candidate
/// selection does no sorting and no allocation — O(changed queues) per
/// mutation, O(1) for `wake_hint`'s earliest-deadline query.
#[derive(Default)]
struct CandidateIndex {
    /// Sorted ascending by `Reverse(key)`, i.e. in-order iteration yields
    /// descending `(deadline, bs, gi, qi)`.
    entries: Vec<Reverse<QueueKey>>,
}

impl CandidateIndex {
    fn insert(&mut self, key: QueueKey) {
        match self.entries.binary_search(&Reverse(key)) {
            Err(pos) => self.entries.insert(pos, Reverse(key)),
            Ok(_) => debug_assert!(false, "duplicate candidate-index entry {key:?}"),
        }
    }

    fn remove(&mut self, key: QueueKey) {
        match self.entries.binary_search(&Reverse(key)) {
            Ok(pos) => {
                self.entries.remove(pos);
            }
            Err(_) => debug_assert!(false, "missing candidate-index entry {key:?}"),
        }
    }

    /// Descending (deadline, bs, gi, qi) — the line-16 scan order.
    fn iter(&self) -> impl Iterator<Item = QueueKey> + '_ {
        self.entries.iter().map(|r| r.0)
    }

    /// Earliest deadline across all non-empty queues (the index is sorted
    /// descending, so it is the last entry). O(1).
    fn earliest_deadline(&self) -> Option<Micros> {
        self.entries.last().map(|r| r.0 .0)
    }
}

struct BsQueue {
    bs: usize,
    hull: DynamicHull,
    deadlines: FibHeap<u64>, // key: deadline µs, value: slab key
    /// This queue's entry in the candidate index (its cached min deadline;
    /// None = not indexed because empty).
    index_key: Option<Micros>,
}

/// The per-model partition of the Algorithm-1 queue set.
struct ModelGroup {
    model: ModelId,
    queues: Vec<BsQueue>,
    /// Entries resident in this group (per-model routing load).
    members: usize,
}

/// The Orloj scheduler (paper §3–4).
pub struct OrlojScheduler {
    cfg: SchedulerConfig,
    ctx: ScoreContext,
    /// Sorted copy of `cfg.batch_sizes` used to build new groups.
    batch_sizes: Vec<usize>,
    groups: Vec<ModelGroup>,
    entries: EntrySlab,
    index: CandidateIndex,
    milestones: BinaryHeap<Reverse<(Micros, u64)>>,
    dropped: Vec<(Request, Outcome)>,
    profiler: OnlineProfiler,
    estimator: Estimator,
    last_refresh: Micros,
    /// Uniform SLO-miss penalty `c` (Fig. 5); relative scores are
    /// insensitive to its absolute value.
    cost_c: f64,
    /// Recycled `per_bs` vectors so the steady-state arrival→dispatch
    /// cycle reuses its own buffers instead of allocating.
    per_bs_pool: Vec<Vec<Option<BsEntry>>>,
    /// Estimator prediction for the batch most recently formed
    /// (telemetry; see `Scheduler::last_batch_prediction`).
    last_prediction: Option<BatchPrediction>,
}

impl OrlojScheduler {
    pub fn new(cfg: SchedulerConfig, seed: u64) -> Self {
        let mut batch_sizes = cfg.batch_sizes.clone();
        batch_sizes.sort_unstable();
        let profiler = OnlineProfiler::new(cfg.profiler_window, cfg.sample_prob, cfg.bins, seed);
        let mut estimator = Estimator::with_score_bins(
            cfg.cost_model,
            cfg.bins,
            cfg.score_bins,
            cfg.feasibility_quantile,
        );
        estimator.set_priority_b(cfg.b);
        estimator.set_model_costs(&cfg.model_costs);
        OrlojScheduler {
            ctx: ScoreContext::new(cfg.b),
            cfg,
            batch_sizes,
            groups: Vec::new(),
            entries: EntrySlab::default(),
            index: CandidateIndex::default(),
            milestones: BinaryHeap::new(),
            dropped: Vec::new(),
            profiler,
            estimator,
            last_refresh: 0,
            cost_c: 1.0,
            per_bs_pool: Vec::new(),
            last_prediction: None,
        }
    }

    /// Seed the profiler with an a-priori distribution for a (model, app)
    /// class and make it visible to the estimator immediately (used at
    /// deployment time the way a production system would import the
    /// previous window).
    pub fn seed_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        self.profiler.seed(model, app, hist, weight);
        self.estimator.refresh(self.profiler.snapshot());
    }

    /// Direct estimator access (diagnostics, tests).
    pub fn estimator_mut(&mut self) -> &mut Estimator {
        &mut self.estimator
    }

    fn rel_ms(&self, t: Micros) -> f64 {
        self.ctx.rel_ms(t)
    }

    /// Index of the group serving `model`, creating it on first arrival
    /// (deterministic: groups appear in arrival order).
    fn group_for(&mut self, model: ModelId) -> usize {
        if let Some(gi) = self.groups.iter().position(|g| g.model == model) {
            return gi;
        }
        let queues = self
            .batch_sizes
            .iter()
            .map(|&bs| BsQueue {
                bs,
                hull: DynamicHull::new(),
                deadlines: FibHeap::new(),
                index_key: None,
            })
            .collect();
        self.groups.push(ModelGroup {
            model,
            queues,
            members: 0,
        });
        self.groups.len() - 1
    }

    /// Re-sync one queue's candidate-index entry after its fib heap
    /// mutated. O(1) when the min deadline is unchanged (the common case —
    /// e.g. an arrival behind the current head).
    fn sync_queue_index(&mut self, gi: usize, qi: usize) {
        let (bs, old, new) = {
            let q = &mut self.groups[gi].queues[qi];
            let new = q.deadlines.min_key();
            if q.index_key == new {
                return;
            }
            let old = q.index_key;
            q.index_key = new;
            (q.bs, old, new)
        };
        if let Some(d) = old {
            self.index.remove((d, bs, gi, qi));
        }
        if let Some(d) = new {
            self.index.insert((d, bs, gi, qi));
        }
    }

    /// Full cross-check of the candidate index against the queue state —
    /// compiled into every debug/test build so any behavior drift of the
    /// incremental maintenance trips immediately. Allocation-free so the
    /// steady-state allocation audit holds in debug builds too.
    #[cfg(debug_assertions)]
    fn debug_assert_index(&self) {
        debug_assert!(
            self.index.entries.windows(2).all(|w| w[0] < w[1]),
            "candidate index unsorted or duplicated"
        );
        let mut nonempty = 0usize;
        for (gi, g) in self.groups.iter().enumerate() {
            for (qi, q) in g.queues.iter().enumerate() {
                debug_assert_eq!(
                    q.index_key,
                    q.deadlines.min_key(),
                    "stale cached min deadline at ({gi},{qi})"
                );
                if let Some(d) = q.index_key {
                    nonempty += 1;
                    debug_assert!(
                        self.index
                            .entries
                            .binary_search(&Reverse((d, q.bs, gi, qi)))
                            .is_ok(),
                        "queue ({gi},{qi}) missing from candidate index"
                    );
                }
            }
        }
        debug_assert_eq!(
            nonempty,
            self.index.entries.len(),
            "candidate index holds entries for empty queues"
        );
    }

    /// Build the per-bs score state for a request at time `now`; returns
    /// None if the batch size is infeasible already. `key` is the slab key
    /// the entry will be stored under (hull point id + fib payload).
    fn build_bs_entry(
        ctx: &ScoreContext,
        estimator: &mut Estimator,
        queue: &mut BsQueue,
        req: &Request,
        now: Micros,
        cost_c: f64,
        key: u64,
    ) -> Option<BsEntry> {
        // Warm-up surcharge first (elastic cold start, 0 when warm): the
        // first post-load batch must fit `deadline - cold_start`, not the
        // steady-state latency alone.
        let warm = estimator.warmup_ms(req.model);
        let bl = estimator.batch_latency(req.model, req.app, queue.bs);
        let feasible = us_to_ms(now) + bl.feasibility_ms + warm <= us_to_ms(req.deadline);
        if !feasible {
            return None;
        }
        // O(1) instantiation of the shared template — no per-bin math.
        let sched = ScoreSchedule::instantiate(&bl.template, ctx, req.deadline, cost_c);
        let coeffs = sched.coeffs_at(ctx.rel_ms(now));
        let point = Point::new(coeffs.alpha, coeffs.beta, key);
        queue.hull.insert(point);
        let fib = queue.deadlines.insert(req.deadline, key);
        Some(BsEntry { sched, point, fib })
    }

    /// Register the next milestone for an entry.
    fn schedule_milestone(&mut self, key: u64, now: Micros) {
        let base = self.ctx.base;
        let entry = match self.entries.get_mut(key) {
            Some(e) => e,
            None => return,
        };
        let rel_now = us_to_ms(now.saturating_sub(base));
        let next = entry
            .per_bs
            .iter()
            .flatten()
            .filter_map(|bse| bse.sched.next_milestone(rel_now))
            .fold(f64::INFINITY, f64::min);
        if next.is_finite() {
            let at = if next <= 0.0 { base } else { base + ms_to_us(next) };
            let at = at.max(now + 1);
            entry.milestone = Some(at);
            self.milestones.push(Reverse((at, key)));
        } else {
            entry.milestone = None;
        }
    }

    /// Lines 5–9: refresh hull points for requests whose milestone passed.
    fn process_milestones(&mut self, now: Micros) {
        while let Some(&Reverse((at, key))) = self.milestones.peek() {
            if at > now {
                break;
            }
            self.milestones.pop();
            // Stale keys (dispatched/dropped entries, or a slot reused by a
            // newer request) fail the generation check and are skipped.
            let valid = self
                .entries
                .get(key)
                .map(|e| e.milestone == Some(at))
                .unwrap_or(false);
            if !valid {
                continue;
            }
            self.refresh_entry_points(key, now);
            self.schedule_milestone(key, now);
        }
    }

    /// Delete + re-insert the hull points of one request at the current
    /// coefficients.
    fn refresh_entry_points(&mut self, key: u64, now: Micros) {
        let rel_now = self.ctx.rel_ms(now);
        if let Some(entry) = self.entries.get_mut(key) {
            let gi = entry.group;
            for (qi, slot) in entry.per_bs.iter_mut().enumerate() {
                if let Some(bse) = slot {
                    let coeffs = bse.sched.coeffs_at(rel_now);
                    let new_point = Point::new(coeffs.alpha, coeffs.beta, key);
                    if new_point.x != bse.point.x || new_point.y != bse.point.y {
                        self.groups[gi].queues[qi].hull.delete(&bse.point);
                        self.groups[gi].queues[qi].hull.insert(new_point);
                        bse.point = new_point;
                    }
                }
            }
        }
    }

    /// Lines 2–4: base-time reset — re-instantiate every schedule (O(1)
    /// each, from the shared templates) and refresh every hull point
    /// against the new base. Deadlines don't change, so the candidate
    /// index is untouched.
    fn reset_base(&mut self, now: Micros) {
        self.ctx.reset(now);
        let rel_now = self.rel_ms(now);
        for slot in 0..self.entries.num_slots() {
            let Some(key) = self.entries.key_at(slot) else {
                continue;
            };
            let entry = self.entries.get_mut(key).unwrap();
            let (deadline, app, model) = (entry.req.deadline, entry.req.app, entry.req.model);
            let gi = entry.group;
            for (qi, bs_slot) in entry.per_bs.iter_mut().enumerate() {
                if let Some(bse) = bs_slot {
                    let bs = self.groups[gi].queues[qi].bs;
                    let bl = self.estimator.batch_latency(model, app, bs);
                    let sched = ScoreSchedule::instantiate(&bl.template, &self.ctx, deadline, self.cost_c);
                    let coeffs = sched.coeffs_at(rel_now);
                    let new_point = Point::new(coeffs.alpha, coeffs.beta, key);
                    self.groups[gi].queues[qi].hull.delete(&bse.point);
                    self.groups[gi].queues[qi].hull.insert(new_point);
                    bse.sched = sched;
                    bse.point = new_point;
                }
            }
            self.schedule_milestone(key, now);
        }
    }

    /// Remove from every queue (request is being dispatched or dropped).
    /// Owns the entry up front, so no per-pop slot collection is needed.
    fn remove_everywhere(&mut self, key: u64) -> Option<Request> {
        let entry = self.entries.remove(key)?;
        let Entry {
            req,
            group: gi,
            mut per_bs,
            ..
        } = entry;
        for (qi, slot) in per_bs.iter_mut().enumerate() {
            if let Some(bse) = slot.take() {
                self.groups[gi].queues[qi].hull.delete(&bse.point);
                self.groups[gi].queues[qi].deadlines.delete(bse.fib);
                self.sync_queue_index(gi, qi);
            }
        }
        per_bs.clear();
        self.per_bs_pool.push(per_bs);
        self.groups[gi].members = self.groups[gi].members.saturating_sub(1);
        Some(req)
    }

    /// Lines 10–14: drop infeasible requests from each queue.
    // Index loops: the body needs split borrows of `groups`, `entries`,
    // `estimator` and `dropped` that iterators would hold across.
    #[allow(clippy::needless_range_loop)]
    fn prune_infeasible(&mut self, now: Micros) {
        let now_ms = us_to_ms(now);
        for gi in 0..self.groups.len() {
            let model = self.groups[gi].model;
            for qi in 0..self.groups[gi].queues.len() {
                let mut changed = false;
                loop {
                    let (deadline, key) = match self.groups[gi].queues[qi].deadlines.min() {
                        Some((d, &k)) => (d, k),
                        None => break,
                    };
                    let app = match self.entries.get(key) {
                        Some(e) => e.req.app,
                        None => {
                            // Stale fib entry should not exist; defensive pop.
                            self.groups[gi].queues[qi].deadlines.pop_min();
                            changed = true;
                            continue;
                        }
                    };
                    let bs = self.groups[gi].queues[qi].bs;
                    let feas = self.estimator.feasibility_ms(model, app, bs);
                    if now_ms + feas <= us_to_ms(deadline) {
                        break; // earliest deadline feasible → rest are too
                    }
                    // Pop from this queue's fib heap and hull.
                    self.groups[gi].queues[qi].deadlines.pop_min();
                    changed = true;
                    let last = {
                        let entry = self.entries.get_mut(key).unwrap();
                        let bse = entry.per_bs[qi].take().expect("fib/slot desync");
                        self.groups[gi].queues[qi].hull.delete(&bse.point);
                        entry.per_bs.iter().all(|s| s.is_none())
                    };
                    if last {
                        // Line 13–14: timed out.
                        if let Some(e) = self.entries.remove(key) {
                            self.groups[gi].members = self.groups[gi].members.saturating_sub(1);
                            let mut per_bs = e.per_bs;
                            per_bs.clear();
                            self.per_bs_pool.push(per_bs);
                            self.dropped.push((e.req, Outcome::TimedOut));
                        }
                    }
                }
                if changed {
                    self.sync_queue_index(gi, qi);
                }
            }
        }
    }

    /// Lines 15–21: candidate queue selection, across every (model, bs)
    /// pair — a plain scan of the persistent index, no sort, no
    /// allocation.
    fn candidate(&self) -> Option<(usize, usize)> {
        #[cfg(debug_assertions)]
        self.debug_assert_index();
        for (_, bs, gi, qi) in self.index.iter() {
            if self.groups[gi].queues[qi].hull.len() >= bs {
                return Some((gi, qi));
            }
        }
        None
    }

    /// Line 22: pop the `bs` top-priority requests from the queue. All
    /// residents of one group share a model, so the batch is model-pure by
    /// construction.
    fn pop_batch(&mut self, gi: usize, qi: usize, now: Micros) -> Vec<Request> {
        let bs = self.groups[gi].queues[qi].bs;
        let m = self.ctx.multiplier(now);
        let mut batch = Vec::with_capacity(bs);
        for _ in 0..bs {
            let top = match self.groups[gi].queues[qi].hull.query_max(m) {
                Some(p) => p,
                None => break,
            };
            if let Some(req) = self.remove_everywhere(top.id) {
                batch.push(req);
            } else {
                break; // defensive: desync
            }
        }
        batch
    }

    fn maybe_refresh_estimator(&mut self, now: Micros) {
        if now.saturating_sub(self.last_refresh) >= self.cfg.refresh_every {
            let snap = self.profiler.snapshot();
            if snap.version != self.estimator.snapshot_version() && !snap.apps.is_empty() {
                self.estimator.refresh(snap);
            }
            self.last_refresh = now;
        }
    }
}

impl Scheduler for OrlojScheduler {
    fn name(&self) -> &'static str {
        "orloj"
    }

    fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        self.seed_profile(model, app, hist, weight);
    }

    fn install_model(&mut self, model: ModelId, cold_start_ms: f64, _now: Micros) {
        // Create the model's queue group eagerly (deterministic group
        // order no longer depends on the first arrival), and charge the
        // cold start into the model's first post-load batch feasibility.
        let _ = self.group_for(model);
        if cold_start_ms > 0.0 {
            self.estimator.set_warmup_ms(model, cold_start_ms);
        }
    }

    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        let Some(gi) = self.groups.iter().position(|g| g.model == model) else {
            return Vec::new();
        };
        // Drain every resident entry of the group back to the caller.
        // The group itself stays as an empty shell: entries store their
        // group *index*, so groups are never removed or reordered (a
        // reinstalled model reuses its shell).
        let mut out = Vec::new();
        for slot in 0..self.entries.num_slots() {
            let Some(key) = self.entries.key_at(slot) else {
                continue;
            };
            let belongs = self.entries.get(key).map(|e| e.group == gi).unwrap_or(false);
            if belongs {
                if let Some(req) = self.remove_everywhere(key) {
                    out.push(req);
                }
            }
        }
        self.estimator.clear_warmup(model);
        debug_assert_eq!(self.groups[gi].members, 0, "evict left residents behind");
        out
    }

    fn reap(&mut self, now: Micros) {
        // Exactly the shedding `next_batch` would perform first (lines
        // 10–14) — no milestone processing, no candidate selection, so a
        // reaped queue forms the same batches it would have anyway.
        self.prune_infeasible(now);
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if self.ctx.needs_reset(now) {
            self.reset_base(now);
        }
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        let gi = self.group_for(req.model);
        let key = self.entries.next_key();
        let mut per_bs = self.per_bs_pool.pop().unwrap_or_default();
        debug_assert!(per_bs.is_empty());
        for queue in self.groups[gi].queues.iter_mut() {
            per_bs.push(Self::build_bs_entry(
                &self.ctx,
                &mut self.estimator,
                queue,
                &req,
                now,
                self.cost_c,
                key,
            ));
        }
        if per_bs.iter().all(|s| s.is_none()) {
            // No feasible batch size at all.
            per_bs.clear();
            self.per_bs_pool.push(per_bs);
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.groups[gi].members += 1;
        let _stored = self.entries.insert(Entry {
            req,
            group: gi,
            per_bs,
            milestone: None,
        });
        debug_assert_eq!(_stored, key, "slab key reservation desync");
        for qi in 0..self.groups[gi].queues.len() {
            self.sync_queue_index(gi, qi);
        }
        self.schedule_milestone(key, now);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        if self.ctx.needs_reset(now) {
            self.reset_base(now);
        }
        self.process_milestones(now);
        self.prune_infeasible(now);
        let (gi, qi) = self.candidate()?;
        let batch = self.pop_batch(gi, qi, now);
        if batch.is_empty() {
            None
        } else {
            // Forming the first post-install batch of a warming model ends
            // its warm-up surcharge: the cold start is being paid by this
            // batch. Clearing at *formation* (not completion) means a
            // stale pre-eviction batch finishing later can never wipe a
            // re-install's fresh surcharge.
            if self.estimator.has_warmup() {
                self.estimator.clear_warmup(batch[0].model);
            }
            // Record the estimator's view of the batch just formed (pure
            // cache lookup + arithmetic; decisions are unaffected, so the
            // golden dispatch snapshots stay bit-identical).
            self.last_prediction = Some(
                self.estimator
                    .batch_latency(batch[0].model, batch[0].app, batch.len())
                    .prediction(),
            );
            Some(batch)
        }
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, now: Micros) {
        for req in batch {
            // The profiler learns each request's *solo* execution time the
            // way the paper's asynchronous profiler does (sampled finished
            // requests re-evaluated alone, off the critical path).
            self.profiler.record(req.model, req.app, req.exec_ms);
        }
        self.maybe_refresh_estimator(now);
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        // Wake at the next milestone or the earliest deadline (whichever is
        // sooner) so prune/milestone work happens on time even when no
        // arrivals/completions occur. Both reads are O(1): the milestone
        // heap's peek and the candidate index's tail.
        let mile = self.milestones.peek().map(|Reverse((t, _))| *t);
        let dl = self.index.earliest_deadline();
        match (mile, dl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn earliest_deadline(&self) -> Option<Micros> {
        // O(1): the candidate index caches the earliest deadline.
        self.index.earliest_deadline()
    }

    fn pending(&self) -> usize {
        self.entries.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.groups
            .iter()
            .find(|g| g.model == model)
            .map_or(0, |g| g.members)
    }

    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        // Drain time under the estimator's distribution tables: resident
        // entries served at the max supported batch size, each request
        // costing the model's mixture mean, plus any pending cold-start
        // surcharge (elastic installs). All pure reads — the entry cache
        // and the dispatch decisions are untouched.
        let n = self.pending_for(model);
        let warm = self.estimator.warmup_ms(model);
        if n == 0 {
            return warm;
        }
        // `batch_sizes` is kept sorted ascending; last = max.
        let bs = *self.batch_sizes.last().unwrap_or(&1);
        let per_batch = self
            .estimator
            .cost_for(model)
            .latency(bs, self.estimator.model_mean_ms(model));
        n.div_ceil(bs) as f64 * per_batch + warm
    }

    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        self.last_prediction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::batchmodel::BatchCostModel;

    const M0: ModelId = ModelId(0);

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            batch_sizes: vec![1, 2, 4, 8],
            cost_model: BatchCostModel::new(0.5, 0.5),
            ..Default::default()
        }
    }

    fn seeded_sched() -> OrlojScheduler {
        let mut s = OrlojScheduler::new(cfg(), 42);
        // One app, exec times around 10 ms.
        let h = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0, 1.0]);
        s.seed_profile(M0, AppId(0), &h, 100);
        s
    }

    fn req(id: u64, release_us: Micros, slo_ms: f64) -> Request {
        Request::new(id, AppId(0), release_us, ms_to_us(slo_ms), 10.0)
    }

    #[test]
    fn empty_scheduler_idles() {
        let mut s = seeded_sched();
        assert!(s.next_batch(0).is_none());
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn single_request_served_at_bs1() {
        let mut s = seeded_sched();
        s.on_arrival(req(1, 0, 500.0), 0);
        assert_eq!(s.pending(), 1);
        assert_eq!(s.pending_for(M0), 1);
        let batch = s.next_batch(1000).expect("batch");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id.0, 1);
        assert_eq!(s.pending(), 0);
        assert_eq!(s.pending_for(M0), 0);
        assert!(s.next_batch(2000).is_none());
    }

    #[test]
    fn batches_fill_to_largest_feasible_size() {
        let mut s = seeded_sched();
        for i in 0..8 {
            s.on_arrival(req(i, 0, 1000.0), 0);
        }
        let batch = s.next_batch(100).expect("batch");
        assert_eq!(batch.len(), 8, "should take the full batch of 8");
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn partial_queue_uses_smaller_size() {
        let mut s = seeded_sched();
        for i in 0..3 {
            s.on_arrival(req(i, 0, 1000.0), 0);
        }
        let batch = s.next_batch(100).expect("batch");
        assert_eq!(batch.len(), 2, "3 pending, sizes {{1,2,4,8}} → Q_2");
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn infeasible_requests_time_out() {
        let mut s = seeded_sched();
        // SLO of 1 ms but exec ~10 ms: infeasible on arrival.
        s.on_arrival(req(1, 0, 1.0), 0);
        assert_eq!(s.pending(), 0);
        let dropped = s.drain_dropped();
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].0.id.0, 1);
    }

    #[test]
    fn queued_request_dropped_when_deadline_nears() {
        let mut s = seeded_sched();
        s.on_arrival(req(1, 0, 40.0), 0); // feasible now (bs=1 ~5.5ms)
        assert_eq!(s.pending(), 1);
        // 38 ms later even bs=1 cannot make it.
        assert!(s.next_batch(ms_to_us(38.0)).is_none());
        assert_eq!(s.pending(), 0);
        assert_eq!(s.pending_for(M0), 0);
        assert_eq!(s.drain_dropped().len(), 1);
    }

    #[test]
    fn urgent_request_leaves_large_queues_first() {
        let mut s = seeded_sched();
        // bs=8 latency ≈ 0.5 + 0.5·8·~12 ≈ 48 ms. Request with 30 ms SLO is
        // feasible only for small sizes.
        s.on_arrival(req(1, 0, 30.0), 0);
        for i in 2..9 {
            s.on_arrival(req(i, 0, 2000.0), 0);
        }
        assert_eq!(s.pending(), 8);
        let batch = s.next_batch(1000).expect("batch");
        // Q_8 holds only the 7 relaxed requests (urgent excluded) → |Q_8|<8
        // → fall through to Q_4 (all 4 from relaxed+urgent mix feasible).
        assert!(batch.len() < 8, "urgent request restricts batch: {}", batch.len());
    }

    #[test]
    fn expired_arrival_dropped_immediately() {
        let mut s = seeded_sched();
        let r = req(1, 0, 10.0);
        s.on_arrival(r, ms_to_us(20.0));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.drain_dropped().len(), 1);
    }

    #[test]
    fn milestones_update_without_panic() {
        let mut s = seeded_sched();
        for i in 0..4 {
            s.on_arrival(req(i, 0, 200.0 + i as f64 * 50.0), 0);
        }
        // Poll through the milestone horizon.
        let mut served = 0;
        let mut t = 0;
        while t < ms_to_us(400.0) {
            if let Some(b) = s.next_batch(t) {
                served += b.len();
                s.on_batch_complete(&b, 10.0, t);
            }
            t += ms_to_us(5.0);
        }
        assert!(served > 0);
        assert_eq!(s.pending() + served + s.drain_dropped().len(), 4);
    }

    #[test]
    fn base_reset_preserves_operation() {
        let mut s = seeded_sched();
        // Jump beyond the reset threshold (b=1e-4/ms → reset past ~400 s).
        let far = ms_to_us(500_000.0);
        s.on_arrival(req(1, far, 500.0), far);
        assert!(s.pending() == 1);
        let batch = s.next_batch(far + 1000).expect("batch after reset");
        assert_eq!(batch.len(), 1);
        // And again much later.
        let far2 = ms_to_us(1_000_000.0);
        s.on_arrival(req(2, far2, 500.0), far2);
        assert_eq!(s.next_batch(far2 + 1000).unwrap().len(), 1);
    }

    #[test]
    fn earlier_deadline_popped_first_within_queue() {
        let mut s = seeded_sched();
        s.on_arrival(req(1, 0, 900.0), 0);
        s.on_arrival(req(2, 0, 80.0), 0); // urgent
        // Only two pending → candidate Q_2 (both feasible); top of the
        // hull at a time close to the urgent deadline must be the urgent
        // request; with batch size 2 both go anyway — check order by
        // serving at bs=1: remove feasibility of 2 by timing.
        let batch = s.next_batch(ms_to_us(1.0)).unwrap();
        assert_eq!(batch.len(), 2);
        // The first popped (highest score) should be the urgent one.
        assert_eq!(batch[0].id.0, 2, "urgent request has the higher score");
    }

    #[test]
    fn profiler_feedback_changes_estimates() {
        let mut s = seeded_sched();
        let before = s.estimator_mut().batch_latency(M0, AppId(0), 4).mean;
        // Complete many slow requests → estimates shift after refresh.
        let reqs: Vec<Request> = (0..200)
            .map(|i| Request::new(100 + i, AppId(0), 0, ms_to_us(10_000.0), 60.0))
            .collect();
        s.on_batch_complete(&reqs, 60.0, 0);
        s.on_batch_complete(&reqs, 60.0, 2_000_000); // past refresh_every
        let after = s.estimator_mut().batch_latency(M0, AppId(0), 4).mean;
        assert!(after > before * 1.5, "{before} -> {after}");
    }

    #[test]
    fn wake_hint_present_when_pending() {
        let mut s = seeded_sched();
        assert!(s.wake_hint(0).is_none());
        s.on_arrival(req(1, 0, 100.0), 0);
        let hint = s.wake_hint(0).expect("hint");
        assert!(hint <= ms_to_us(100.0));
    }

    #[test]
    fn wake_hint_matches_full_scan() {
        // Satellite: wake_hint serves from the O(1) cached index; it must
        // equal the historical full scan over every queue's fib-heap min.
        let mut s = seeded_sched();
        for i in 0..12 {
            s.on_arrival(req(i, 0, 80.0 + 37.0 * i as f64), ms_to_us(i as f64));
        }
        let _ = s.next_batch(ms_to_us(15.0));
        let scan_dl = s
            .groups
            .iter()
            .flat_map(|g| g.queues.iter())
            .filter_map(|q| q.deadlines.min_key())
            .min();
        let mile = s.milestones.peek().map(|Reverse((t, _))| *t);
        let expect = match (mile, scan_dl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        assert_eq!(s.wake_hint(ms_to_us(15.0)), expect);
    }

    #[test]
    fn slab_slots_recycle_under_churn() {
        // Long arrival→dispatch→drop churn: slot reuse with generation
        // bumps must keep every invariant (the candidate-index cross-check
        // in candidate() runs on every iteration in debug builds), and the
        // slab must not grow past the high-water mark of pending entries.
        let mut s = seeded_sched();
        let mut t = 0u64;
        let mut served = 0usize;
        let mut dropped = 0usize;
        let mut next_id = 0u64;
        for round in 0..200 {
            for _ in 0..3 {
                // Mix of roomy and hopelessly tight SLOs → both dispatch
                // and prune paths recycle slots.
                let slo = if next_id % 5 == 4 { 12.0 } else { 400.0 };
                s.on_arrival(req(next_id, t, slo), t);
                next_id += 1;
            }
            t += ms_to_us(7.0);
            if let Some(b) = s.next_batch(t) {
                served += b.len();
                s.on_batch_complete(&b, 10.0, t);
            }
            dropped += s.drain_dropped().len();
            if round == 100 {
                assert!(s.entries.num_slots() <= 64, "slab should stay compact");
            }
        }
        // Drain the tail.
        let mut guard = 0;
        while s.pending() > 0 && guard < 10_000 {
            t += ms_to_us(5.0);
            if let Some(b) = s.next_batch(t) {
                served += b.len();
                s.on_batch_complete(&b, 10.0, t);
            }
            dropped += s.drain_dropped().len();
            guard += 1;
        }
        assert_eq!(served + dropped, next_id as usize, "conservation under churn");
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn install_creates_group_and_warmup_gates_feasibility() {
        let mut s = seeded_sched();
        // Install a second model with a 100 ms cold-start surcharge.
        s.install_model(ModelId(1), 100.0, 0);
        let h = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0]);
        s.seed_profile(ModelId(1), AppId(0), &h, 100);
        assert_eq!(s.pending_for(ModelId(1)), 0);
        // An 80 ms SLO fits the steady state (~10 ms) but not warm-up +
        // steady state → dropped on arrival.
        s.on_arrival(
            Request::new(1, AppId(0), 0, ms_to_us(80.0), 10.0).with_model(ModelId(1)),
            0,
        );
        assert_eq!(s.pending(), 0, "warm-up surcharge must gate admission");
        assert_eq!(s.drain_dropped().len(), 1);
        // A roomy SLO is admitted; *forming* its batch ends warm-up (the
        // cold start is paid by that batch — and a stale pre-eviction
        // batch completing later can never wipe a fresh surcharge).
        s.on_arrival(
            Request::new(2, AppId(0), 0, ms_to_us(2_000.0), 10.0).with_model(ModelId(1)),
            0,
        );
        let batch = s.next_batch(1_000).expect("warm-up batch");
        assert_eq!(batch.len(), 1);
        s.on_batch_complete(&batch, 110.0, ms_to_us(110.0));
        // Post-warm-up the 80 ms SLO is feasible again.
        let t = ms_to_us(200.0);
        s.on_arrival(
            Request::new(3, AppId(0), t, ms_to_us(80.0), 10.0).with_model(ModelId(1)),
            t,
        );
        assert_eq!(s.pending(), 1, "surcharge cleared after the first batch");
    }

    #[test]
    fn evict_drains_residents_and_leaves_other_models() {
        let mut s = OrlojScheduler::new(cfg(), 42);
        let h = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0, 1.0]);
        s.seed_profile(ModelId(0), AppId(0), &h, 100);
        s.seed_profile(ModelId(1), AppId(0), &h, 100);
        for i in 0..6u64 {
            let model = ModelId((i % 2) as u32);
            s.on_arrival(
                Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 10.0).with_model(model),
                0,
            );
        }
        assert_eq!(s.pending(), 6);
        let drained = s.evict_model(ModelId(0));
        let mut ids: Vec<u64> = drained.iter().map(|r| r.id.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 2, 4]);
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pending_for(ModelId(0)), 0);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        // The survivors still schedule (model 1's group untouched), and
        // the evicted model's shell accepts a reinstall + new arrivals.
        let b = s.next_batch(1_000).expect("model 1 still schedulable");
        assert!(b.iter().all(|r| r.model == ModelId(1)));
        s.install_model(ModelId(0), 0.0, 2_000);
        s.on_arrival(
            Request::new(9, AppId(0), 2_000, ms_to_us(5_000.0), 10.0).with_model(ModelId(0)),
            2_000,
        );
        assert_eq!(s.pending_for(ModelId(0)), 1);
        assert!(s.evict_model(ModelId(7)).is_empty(), "unknown model no-ops");
    }

    #[test]
    fn reap_matches_next_batch_shedding() {
        // Reaping at t must drop exactly what next_batch(t) would drop
        // before forming a batch — same policy, earlier bookkeeping.
        let mk = || {
            let mut s = seeded_sched();
            s.on_arrival(req(1, 0, 40.0), 0); // doomed by t = 38 ms
            s.on_arrival(req(2, 0, 2_000.0), 0); // comfortable
            s
        };
        let t = ms_to_us(38.0);
        let mut reaped = mk();
        reaped.reap(t);
        let dropped_by_reap: Vec<u64> =
            reaped.drain_dropped().iter().map(|(r, _)| r.id.0).collect();
        assert_eq!(dropped_by_reap, vec![1]);
        assert_eq!(reaped.pending(), 1);
        // The subsequent batch is identical to the un-reaped path's.
        let mut plain = mk();
        let a = reaped.next_batch(t).expect("batch");
        let b = plain.next_batch(t).expect("batch");
        assert_eq!(
            a.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            b.iter().map(|r| r.id.0).collect::<Vec<_>>()
        );
        assert_eq!(plain.drain_dropped().len(), 1, "same shed either way");
    }

    #[test]
    fn co_located_models_batch_separately() {
        let mut s = OrlojScheduler::new(cfg(), 42);
        let fast = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0, 1.0]);
        let slow = Histogram::from_weights(70.0, 2.0, &[1.0, 2.0, 1.0]);
        s.seed_profile(ModelId(0), AppId(0), &fast, 100);
        s.seed_profile(ModelId(1), AppId(0), &slow, 100);
        // Interleave four requests per model, all with roomy SLOs.
        for i in 0..8u64 {
            let model = ModelId((i % 2) as u32);
            s.on_arrival(
                Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 10.0).with_model(model),
                0,
            );
        }
        assert_eq!(s.pending(), 8);
        assert_eq!(s.pending_for(ModelId(0)), 4);
        assert_eq!(s.pending_for(ModelId(1)), 4);
        // Every batch the scheduler forms is model-pure, and both models
        // eventually drain.
        let mut served = [0usize; 2];
        let mut t = 1_000;
        while s.pending() > 0 {
            if let Some(b) = s.next_batch(t) {
                let m = b[0].model;
                assert!(
                    b.iter().all(|r| r.model == m),
                    "mixed-model batch: {:?}",
                    b.iter().map(|r| r.model).collect::<Vec<_>>()
                );
                served[m.0 as usize] += b.len();
                s.on_batch_complete(&b, 10.0, t);
            }
            t += ms_to_us(5.0);
        }
        assert_eq!(served, [4, 4]);
        assert!(s.drain_dropped().is_empty());
    }
}
