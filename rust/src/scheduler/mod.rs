//! Scheduler interface shared by Orloj and the baselines.
//!
//! The same trait runs against the discrete-event simulator (virtual time)
//! and the PJRT serving loop (real time): the scheduler only ever sees
//! timestamps, arrivals and completions.

pub mod estimator;
pub mod orloj;
pub mod profiler;

use crate::clock::Micros;
use crate::core::batchmodel::BatchCostModel;
use crate::core::request::{Outcome, Request};

/// Shared scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Batch sizes the model supports (paper: `S`).
    pub batch_sizes: Vec<usize>,
    /// Anticipated-delay parameter `b` (1/ms; paper default 1e-4).
    pub b: f64,
    /// Histogram resolution for derived distributions.
    pub bins: usize,
    /// Coarser resolution used for the priority-score schedules (§Perf:
    /// each bin contributes up to two milestones per request per queue, so
    /// score bins directly control hull churn).
    pub score_bins: usize,
    /// Batch cost model (profiled on the real path; configured in sim).
    pub cost_model: BatchCostModel,
    /// Quantile of the batch-latency distribution used in the feasibility
    /// check (Algorithm 1 line 11). 0.5 ≈ median; higher is more
    /// conservative.
    pub feasibility_quantile: f64,
    /// Online profiler window (samples kept per app).
    pub profiler_window: usize,
    /// Fraction of completions sampled by the profiler.
    pub sample_prob: f64,
    /// How often the estimator picks up new profiler data (µs).
    pub refresh_every: Micros,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            batch_sizes: vec![1, 2, 4, 8, 16],
            b: 1e-4,
            bins: 64,
            score_bins: 16,
            cost_model: BatchCostModel::gpu_like(),
            feasibility_quantile: 0.5,
            profiler_window: 2048,
            sample_prob: 1.0,
            refresh_every: 1_000_000, // 1 s
        }
    }
}

/// A scheduling policy. Drives one worker (the paper's per-GPU scheduler;
/// scale-out runs one scheduler per model replica).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Install deployment-time historical data for an app. Orloj keeps the
    /// full distribution; point-estimate systems reduce it to their
    /// statistic; reactive systems ignore it. Default: ignore.
    fn seed_app_profile(
        &mut self,
        _app: crate::core::request::AppId,
        _hist: &crate::core::histogram::Histogram,
        _weight: u64,
    ) {
    }

    /// A request entered the system.
    fn on_arrival(&mut self, req: Request, now: Micros);

    /// The worker is free: pick the next batch, or None to stay idle.
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>>;

    /// A batch finished; `batch_ms` is its measured wall time. Feeds the
    /// online profiler / reactive controllers.
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros);

    /// Requests dropped by the scheduler since the last call, with the
    /// reason (TimedOut for queue drops, Aborted for failed execution
    /// slots à la Clockwork).
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)>;

    /// Next time the scheduler wants to be polled even without new events
    /// (milestones, windows). None = only poll on arrivals/completions.
    fn wake_hint(&self, now: Micros) -> Option<Micros>;

    /// Number of queued (not yet executing) requests.
    fn pending(&self) -> usize;
}

/// Mutable borrows are schedulers too, so the clock-generic serving core
/// (`serve::ServingLoop`) can drive a scheduler it does not own — e.g. the
/// single-worker `sim::engine::run` compatibility shim.
impl<'a, S: Scheduler + ?Sized> Scheduler for &'a mut S {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(
        &mut self,
        app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        weight: u64,
    ) {
        (**self).seed_app_profile(app, hist, weight)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
}

impl Scheduler for Box<dyn Scheduler> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(
        &mut self,
        app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        weight: u64,
    ) {
        (**self).seed_app_profile(app, hist, weight)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
}
