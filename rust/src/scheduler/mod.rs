//! Scheduler interface shared by Orloj and the baselines.
//!
//! The same trait runs against the discrete-event simulator (virtual time)
//! and the PJRT serving loop (real time): the scheduler only ever sees
//! timestamps, arrivals and completions. One scheduler instance may serve
//! several co-located *models* (cluster placement, DESIGN.md §3); batches
//! are always model-pure and the profiling tables are keyed by
//! `(model, app)` so co-located models never cross-contaminate each
//! other's distributions.

pub mod estimator;
pub mod orloj;
pub mod profiler;

use crate::clock::Micros;
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId, Outcome, Request};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Shared scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Batch sizes the model supports (paper: `S`).
    pub batch_sizes: Vec<usize>,
    /// Anticipated-delay parameter `b` (1/ms; paper default 1e-4).
    pub b: f64,
    /// Histogram resolution for derived distributions.
    pub bins: usize,
    /// Coarser resolution used for the priority-score schedules (§Perf:
    /// each bin contributes up to two milestones per request per queue, so
    /// score bins directly control hull churn).
    pub score_bins: usize,
    /// Batch cost model (profiled on the real path; configured in sim).
    /// The fallback when `model_costs` has no entry for a request's model.
    pub cost_model: BatchCostModel,
    /// Per-model batch cost models for heterogeneous co-located models
    /// (empty = every model uses `cost_model`).
    pub model_costs: Vec<(u32, BatchCostModel)>,
    /// Quantile of the batch-latency distribution used in the feasibility
    /// check (Algorithm 1 line 11). 0.5 ≈ median; higher is more
    /// conservative.
    pub feasibility_quantile: f64,
    /// Online profiler window (samples kept per app).
    pub profiler_window: usize,
    /// Fraction of completions sampled by the profiler.
    pub sample_prob: f64,
    /// How often the estimator picks up new profiler data (µs).
    pub refresh_every: Micros,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            batch_sizes: vec![1, 2, 4, 8, 16],
            b: 1e-4,
            bins: 64,
            score_bins: 16,
            cost_model: BatchCostModel::gpu_like(),
            model_costs: Vec::new(),
            feasibility_quantile: 0.5,
            profiler_window: 2048,
            sample_prob: 1.0,
            refresh_every: 1_000_000, // 1 s
        }
    }
}

/// Per-model pending counters: the bookkeeping schedulers use to answer
/// [`Scheduler::pending_for`] without scanning their queues (routing calls
/// it once per candidate worker per arrival — it sits on the hot path).
#[derive(Debug, Default)]
pub struct ModelPending(Vec<(ModelId, usize)>);

impl ModelPending {
    pub fn new() -> Self {
        ModelPending(Vec::new())
    }

    pub fn inc(&mut self, model: ModelId) {
        match self.0.iter_mut().find(|(m, _)| *m == model) {
            Some((_, c)) => *c += 1,
            None => self.0.push((model, 1)),
        }
    }

    pub fn dec(&mut self, model: ModelId) {
        if let Some((_, c)) = self.0.iter_mut().find(|(m, _)| *m == model) {
            *c = c.saturating_sub(1);
        }
    }

    pub fn get(&self, model: ModelId) -> usize {
        self.0
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(0, |(_, c)| *c)
    }
}

/// Pop up to `take` requests of `model` from a FIFO queue, preserving the
/// relative order of other models' entries (the shared model-pure batch
/// fill for FIFO baselines — Clipper, Nexus).
pub fn drain_fifo_model(
    queue: &mut VecDeque<Request>,
    counts: &mut ModelPending,
    model: ModelId,
    take: usize,
) -> Vec<Request> {
    let mut batch = Vec::with_capacity(take);
    let mut i = 0;
    while i < queue.len() && batch.len() < take {
        if queue[i].model == model {
            let r = queue.remove(i).unwrap();
            counts.dec(model);
            batch.push(r);
        } else {
            i += 1;
        }
    }
    batch
}

/// Pop up to `take` requests of `model` in deadline order from an EDF
/// heap (`(deadline, id)` min-heap + id→request map), re-pushing skipped
/// entries of other models untouched and discarding stale heap entries
/// (the shared model-pure batch fill for EDF-ordered baselines — EDF,
/// Clockwork).
pub fn drain_edf_model(
    queue: &mut BinaryHeap<Reverse<(Micros, u64)>>,
    by_seq: &mut HashMap<u64, Request>,
    counts: &mut ModelPending,
    model: ModelId,
    take: usize,
) -> Vec<Request> {
    let mut batch = Vec::with_capacity(take);
    let mut skipped: Vec<Reverse<(Micros, u64)>> = Vec::new();
    while batch.len() < take {
        let Some(Reverse((d, seq))) = queue.pop() else {
            break;
        };
        match by_seq.get(&seq) {
            Some(r) if r.model == model => {
                let r = by_seq.remove(&seq).unwrap();
                counts.dec(model);
                batch.push(r);
            }
            Some(_) => skipped.push(Reverse((d, seq))),
            None => {} // stale heap entry: already dispatched/dropped
        }
    }
    queue.extend(skipped);
    batch
}

/// A scheduling policy. Drives one worker (the paper's per-GPU scheduler;
/// scale-out runs one scheduler per replica, each possibly hosting
/// several models).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Install deployment-time historical data for one `(model, app)`
    /// traffic class. Orloj keeps the full distribution; point-estimate
    /// systems reduce it to their statistic; reactive systems ignore it.
    /// Default: ignore.
    fn seed_app_profile(&mut self, _model: ModelId, _app: AppId, _hist: &Histogram, _weight: u64) {}

    /// A request entered the system.
    fn on_arrival(&mut self, req: Request, now: Micros);

    /// The worker is free: pick the next batch, or None to stay idle.
    /// Returned batches are always model-pure (one model per batch).
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>>;

    /// A batch finished; `batch_ms` is its measured wall time. Feeds the
    /// online profiler / reactive controllers.
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros);

    /// Requests dropped by the scheduler since the last call, with the
    /// reason (TimedOut for queue drops, Aborted for failed execution
    /// slots à la Clockwork).
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)>;

    /// Next time the scheduler wants to be polled even without new events
    /// (milestones, windows). None = only poll on arrivals/completions.
    fn wake_hint(&self, now: Micros) -> Option<Micros>;

    /// Number of queued (not yet executing) requests.
    fn pending(&self) -> usize;

    /// Number of queued requests for one model (per-model load accounting
    /// for the routers).
    fn pending_for(&self, model: ModelId) -> usize;
}

/// Mutable borrows are schedulers too, so the clock-generic serving core
/// (`serve::ServingLoop`) can drive a scheduler it does not own — e.g. the
/// single-worker `sim::engine::run` compatibility shim.
impl<S: Scheduler + ?Sized> Scheduler for &mut S {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        (**self).seed_app_profile(model, app, hist, weight)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
    fn pending_for(&self, model: ModelId) -> usize {
        (**self).pending_for(model)
    }
}

impl Scheduler for Box<dyn Scheduler> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        (**self).seed_app_profile(model, app, hist, weight)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
    fn pending_for(&self, model: ModelId) -> usize {
        (**self).pending_for(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: u32, slo_us: Micros) -> Request {
        Request::new(id, AppId(0), 0, slo_us, 5.0).with_model(ModelId(model))
    }

    #[test]
    fn drain_fifo_model_preserves_other_models_order() {
        let mut q: VecDeque<Request> = VecDeque::new();
        let mut counts = ModelPending::new();
        for i in 0..6 {
            let r = req(i, (i % 2) as u32, 1_000_000);
            counts.inc(r.model);
            q.push_back(r);
        }
        let batch = drain_fifo_model(&mut q, &mut counts, ModelId(0), 2);
        assert_eq!(batch.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(counts.get(ModelId(0)), 1);
        // Remaining queue keeps its relative order: 1, 3, 4, 5.
        assert_eq!(
            q.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            vec![1, 3, 4, 5]
        );
    }

    #[test]
    fn drain_edf_model_repushes_skipped_and_skips_stale() {
        let mut heap: BinaryHeap<Reverse<(Micros, u64)>> = BinaryHeap::new();
        let mut by_seq: HashMap<u64, Request> = HashMap::new();
        let mut counts = ModelPending::new();
        for i in 0..6u64 {
            let r = req(i, (i % 2) as u32, 1_000 * (i + 1));
            heap.push(Reverse((r.deadline, i)));
            counts.inc(r.model);
            by_seq.insert(i, r);
        }
        // A stale heap entry (id 9 has no by_seq record) is discarded.
        heap.push(Reverse((1, 9)));
        let batch = drain_edf_model(&mut heap, &mut by_seq, &mut counts, ModelId(1), 2);
        // Model 1 in deadline order: ids 1, 3.
        assert_eq!(batch.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(counts.get(ModelId(1)), 1);
        // Skipped model-0 entries are back in the heap, still popping in
        // deadline order.
        let next = drain_edf_model(&mut heap, &mut by_seq, &mut counts, ModelId(0), 3);
        assert_eq!(next.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 2, 4]);
    }

    #[test]
    fn model_pending_counts() {
        let mut p = ModelPending::new();
        assert_eq!(p.get(ModelId(0)), 0);
        p.inc(ModelId(0));
        p.inc(ModelId(0));
        p.inc(ModelId(1));
        assert_eq!(p.get(ModelId(0)), 2);
        assert_eq!(p.get(ModelId(1)), 1);
        p.dec(ModelId(0));
        assert_eq!(p.get(ModelId(0)), 1);
        // Underflow saturates; unknown models decrement to nothing.
        p.dec(ModelId(9));
        p.dec(ModelId(1));
        p.dec(ModelId(1));
        assert_eq!(p.get(ModelId(1)), 0);
    }
}
