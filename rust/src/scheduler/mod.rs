//! Scheduler interface shared by Orloj and the baselines.
//!
//! The same trait runs against the discrete-event simulator (virtual time)
//! and the PJRT serving loop (real time): the scheduler only ever sees
//! timestamps, arrivals and completions. One scheduler instance may serve
//! several co-located *models* (cluster placement, DESIGN.md §3); batches
//! are always model-pure and the profiling tables are keyed by
//! `(model, app)` so co-located models never cross-contaminate each
//! other's distributions.

pub mod estimator;
pub mod orloj;
pub mod profiler;

use crate::clock::Micros;
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId, Outcome, Request};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Shared scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Batch sizes the model supports (paper: `S`).
    pub batch_sizes: Vec<usize>,
    /// Anticipated-delay parameter `b` (1/ms; paper default 1e-4).
    pub b: f64,
    /// Histogram resolution for derived distributions.
    pub bins: usize,
    /// Coarser resolution used for the priority-score schedules (§Perf:
    /// each bin contributes up to two milestones per request per queue, so
    /// score bins directly control hull churn).
    pub score_bins: usize,
    /// Batch cost model (profiled on the real path; configured in sim).
    /// The fallback when `model_costs` has no entry for a request's model.
    pub cost_model: BatchCostModel,
    /// Per-model batch cost models for heterogeneous co-located models
    /// (empty = every model uses `cost_model`).
    pub model_costs: Vec<(u32, BatchCostModel)>,
    /// Quantile of the batch-latency distribution used in the feasibility
    /// check (Algorithm 1 line 11). 0.5 ≈ median; higher is more
    /// conservative.
    pub feasibility_quantile: f64,
    /// Online profiler window (samples kept per app).
    pub profiler_window: usize,
    /// Fraction of completions sampled by the profiler.
    pub sample_prob: f64,
    /// How often the estimator picks up new profiler data (µs).
    pub refresh_every: Micros,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            batch_sizes: vec![1, 2, 4, 8, 16],
            b: 1e-4,
            bins: 64,
            score_bins: 16,
            cost_model: BatchCostModel::gpu_like(),
            model_costs: Vec::new(),
            feasibility_quantile: 0.5,
            profiler_window: 2048,
            sample_prob: 1.0,
            refresh_every: 1_000_000, // 1 s
        }
    }
}

/// Per-model FIFO sub-queues with a shared arrival order (§Perf).
///
/// The historical layout was one global `VecDeque` with an O(n) scan-and-
/// `remove(i)` per popped request when filling a model-pure batch. Here
/// each model owns its own FIFO lane; `push` stamps a monotone sequence
/// number so the *global* head (earliest arrival across lanes — what
/// head-of-queue policies like Clipper/Nexus key their decisions on) is an
/// O(models) peek, and a model-pure batch fill is O(batch) pops from one
/// lane. Lane lookup is a linear probe over the handful of co-located
/// models — no hashing.
#[derive(Debug, Default)]
pub struct FifoQueues {
    lanes: Vec<(ModelId, VecDeque<(u64, Request)>)>,
    next_seq: u64,
    len: usize,
}

impl FifoQueues {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: Request) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let model = req.model;
        match self.lanes.iter_mut().find(|(m, _)| *m == model) {
            Some((_, lane)) => lane.push_back((seq, req)),
            None => {
                let mut lane = VecDeque::new();
                lane.push_back((seq, req));
                self.lanes.push((model, lane));
            }
        }
    }

    /// Create `model`'s (empty) lane if absent — the elastic
    /// `install_model` hook, so a freshly loaded model's queue state
    /// exists before its first arrival.
    pub fn ensure_lane(&mut self, model: ModelId) {
        if !self.lanes.iter().any(|(m, _)| *m == model) {
            self.lanes.push((model, VecDeque::new()));
        }
    }

    /// Tear down `model`'s lane (elastic `evict_model`), returning its
    /// queued requests in arrival order so the serving core can re-route
    /// them instead of dropping them.
    pub fn remove_lane(&mut self, model: ModelId) -> Vec<Request> {
        match self.lanes.iter().position(|(m, _)| *m == model) {
            Some(i) => {
                let (_, lane) = self.lanes.remove(i);
                self.len -= lane.len();
                lane.into_iter().map(|(_, r)| r).collect()
            }
            None => Vec::new(),
        }
    }

    /// Index of the lane holding the global FIFO head.
    fn head_lane(&self) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, (_, lane))| lane.front().map(|(seq, _)| (*seq, i)))
            .min()
            .map(|(_, i)| i)
    }

    /// The earliest-arrived request across all models.
    pub fn front(&self) -> Option<&Request> {
        self.head_lane()
            .map(|i| &self.lanes[i].1.front().unwrap().1)
    }

    /// Pop the global FIFO head.
    pub fn pop_front(&mut self) -> Option<Request> {
        let i = self.head_lane()?;
        self.len -= 1;
        Some(self.lanes[i].1.pop_front().unwrap().1)
    }

    /// Pop up to `take` requests of `model` in arrival order — O(batch).
    pub fn drain_model(&mut self, model: ModelId, take: usize) -> Vec<Request> {
        let mut batch = Vec::with_capacity(take);
        if let Some((_, lane)) = self.lanes.iter_mut().find(|(m, _)| *m == model) {
            while batch.len() < take {
                match lane.pop_front() {
                    Some((_, r)) => {
                        self.len -= 1;
                        batch.push(r);
                    }
                    None => break,
                }
            }
        }
        batch
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued requests of one model — O(1) per lane, no counters to keep
    /// in sync (routing calls this once per candidate worker per arrival).
    pub fn pending_for(&self, model: ModelId) -> usize {
        self.lanes
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(0, |(_, lane)| lane.len())
    }

    /// The earliest-arrived request among models satisfying `pred` — the
    /// global FIFO head restricted to a subset of lanes. The best-effort
    /// admission lane drains with this (only models the idle worker
    /// actually hosts are eligible); O(models), no allocation.
    pub fn front_matching(&self, pred: impl Fn(ModelId) -> bool) -> Option<&Request> {
        self.lanes
            .iter()
            .filter(|(m, _)| pred(*m))
            .filter_map(|(_, lane)| lane.front().map(|(seq, r)| (*seq, r)))
            .min_by_key(|(seq, _)| *seq)
            .map(|(_, r)| r)
    }
}

/// A heap item ordered by (deadline, request id) — the tie-break the
/// historical `(deadline, id)` global heap used.
#[derive(Debug)]
struct EdfItem(Request);

impl PartialEq for EdfItem {
    fn eq(&self, other: &Self) -> bool {
        self.0.deadline == other.0.deadline && self.0.id == other.0.id
    }
}

impl Eq for EdfItem {}

impl PartialOrd for EdfItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EdfItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.0.deadline, self.0.id.0).cmp(&(other.0.deadline, other.0.id.0))
    }
}

/// Per-model earliest-deadline-first sub-queues (§Perf).
///
/// The historical layout was one global `(deadline, id)` heap plus an
/// id→request hash map, with model-pure fills popping and *re-pushing*
/// every skipped entry of other models (O(n log n) per batch worst case).
/// Here each model owns its own deadline heap carrying the requests
/// inline: the global EDF head is an O(models) peek over lane minima, a
/// model-pure fill is O(batch·log lane), and there is no hash map and no
/// stale-entry bookkeeping at all.
#[derive(Debug, Default)]
pub struct EdfQueues {
    lanes: Vec<(ModelId, BinaryHeap<Reverse<EdfItem>>)>,
    len: usize,
}

impl EdfQueues {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: Request) {
        self.len += 1;
        let model = req.model;
        match self.lanes.iter_mut().find(|(m, _)| *m == model) {
            Some((_, lane)) => lane.push(Reverse(EdfItem(req))),
            None => {
                let mut lane = BinaryHeap::new();
                lane.push(Reverse(EdfItem(req)));
                self.lanes.push((model, lane));
            }
        }
    }

    /// Create `model`'s (empty) lane if absent — the elastic
    /// `install_model` hook.
    pub fn ensure_lane(&mut self, model: ModelId) {
        if !self.lanes.iter().any(|(m, _)| *m == model) {
            self.lanes.push((model, BinaryHeap::new()));
        }
    }

    /// Tear down `model`'s lane (elastic `evict_model`), returning its
    /// queued requests in deadline order so the serving core can re-route
    /// them instead of dropping them.
    pub fn remove_lane(&mut self, model: ModelId) -> Vec<Request> {
        match self.lanes.iter().position(|(m, _)| *m == model) {
            Some(i) => {
                let (_, lane) = self.lanes.remove(i);
                self.len -= lane.len();
                let mut out: Vec<Request> =
                    lane.into_iter().map(|Reverse(EdfItem(r))| r).collect();
                out.sort_by_key(|r| (r.deadline, r.id.0));
                out
            }
            None => Vec::new(),
        }
    }

    /// Index of the lane holding the global EDF head (min (deadline, id)).
    fn head_lane(&self) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, (_, lane))| {
                lane.peek()
                    .map(|Reverse(item)| ((item.0.deadline, item.0.id.0), i))
            })
            .min()
            .map(|(_, i)| i)
    }

    /// The earliest-deadline request across all models.
    pub fn peek(&self) -> Option<&Request> {
        self.head_lane()
            .map(|i| &self.lanes[i].1.peek().unwrap().0 .0)
    }

    /// Pop the global EDF head.
    pub fn pop_head(&mut self) -> Option<Request> {
        let i = self.head_lane()?;
        self.len -= 1;
        Some(self.lanes[i].1.pop().unwrap().0 .0)
    }

    /// Earliest deadline across all models (wake hints) — O(models).
    pub fn min_deadline(&self) -> Option<Micros> {
        self.lanes
            .iter()
            .filter_map(|(_, lane)| lane.peek().map(|Reverse(item)| item.0.deadline))
            .min()
    }

    /// Pop up to `take` requests of `model` in deadline order — O(batch·
    /// log lane), nothing re-pushed.
    pub fn drain_model(&mut self, model: ModelId, take: usize) -> Vec<Request> {
        let mut batch = Vec::with_capacity(take);
        if let Some((_, lane)) = self.lanes.iter_mut().find(|(m, _)| *m == model) {
            while batch.len() < take {
                match lane.pop() {
                    Some(Reverse(EdfItem(r))) => {
                        self.len -= 1;
                        batch.push(r);
                    }
                    None => break,
                }
            }
        }
        batch
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued requests of one model — O(1) per lane.
    pub fn pending_for(&self, model: ModelId) -> usize {
        self.lanes
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(0, |(_, lane)| lane.len())
    }
}

/// The scheduler's latency prediction for the batch it most recently
/// formed: the expected exec time plus a variance band. Orloj reports the
/// p10/p90 of its estimated batch-latency distribution (paper Eq. 1–2);
/// point-estimate systems report a degenerate band around their statistic.
/// Consumed by the telemetry recorder at batch formation, so calibration
/// (predicted vs. realized) can be measured per (model, app).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPrediction {
    /// Expected batch execution time, ms.
    pub ms: f64,
    /// Lower edge of the variance band (Orloj: p10), ms.
    pub lo_ms: f64,
    /// Upper edge of the variance band (Orloj: p90), ms.
    pub hi_ms: f64,
}

impl BatchPrediction {
    /// A degenerate band for point-estimate schedulers: ±`frac` around the
    /// point prediction.
    pub fn point(ms: f64, frac: f64) -> BatchPrediction {
        BatchPrediction {
            ms,
            lo_ms: ms * (1.0 - frac),
            hi_ms: ms * (1.0 + frac),
        }
    }
}

/// A scheduling policy. Drives one worker (the paper's per-GPU scheduler;
/// scale-out runs one scheduler per replica, each possibly hosting
/// several models).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Install deployment-time historical data for one `(model, app)`
    /// traffic class. Orloj keeps the full distribution; point-estimate
    /// systems reduce it to their statistic; reactive systems ignore it.
    /// Default: ignore.
    fn seed_app_profile(&mut self, _model: ModelId, _app: AppId, _hist: &Histogram, _weight: u64) {}

    /// A model finished loading onto this replica (elastic placement):
    /// create its per-model queue state, and charge `cold_start_ms` into
    /// the model's first post-load batch's expected latency so the SLO
    /// math stays honest during warm-up (DESIGN.md §8). Default: queue
    /// state appears lazily on first arrival and no surcharge is applied.
    fn install_model(&mut self, _model: ModelId, _cold_start_ms: f64, _now: Micros) {}

    /// A model left this replica (elastic placement): tear down its queue
    /// state and return the queued requests so the serving core can
    /// re-route them to the remaining hosts — evictions drain, they never
    /// drop (DESIGN.md §8). Default: nothing hosted, nothing to drain.
    fn evict_model(&mut self, _model: ModelId) -> Vec<Request> {
        Vec::new()
    }

    /// Shed queued requests that this policy would drop at its next
    /// batch-formation opportunity anyway. Called by the serving core on
    /// `Wake` for replicas whose worker is busy (they never reach
    /// `next_batch` mid-batch, so doomed requests would otherwise inflate
    /// the load counts routers see). Must shed exactly the policy's own
    /// next-dequeue discipline — never more. Default: no-op.
    fn reap(&mut self, _now: Micros) {}

    /// A request entered the system.
    fn on_arrival(&mut self, req: Request, now: Micros);

    /// The worker is free: pick the next batch, or None to stay idle.
    /// Returned batches are always model-pure (one model per batch).
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>>;

    /// A batch finished; `batch_ms` is its measured wall time. Feeds the
    /// online profiler / reactive controllers.
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros);

    /// Requests dropped by the scheduler since the last call, with the
    /// reason (TimedOut for queue drops, Aborted for failed execution
    /// slots à la Clockwork).
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)>;

    /// Next time the scheduler wants to be polled even without new events
    /// (milestones, windows). None = only poll on arrivals/completions.
    fn wake_hint(&self, now: Micros) -> Option<Micros>;

    /// Deadline of the queued request this policy would act on soonest
    /// (its own dequeue discipline's head). The virtual-time pumps use it
    /// as the idle-advance bound when `wake_hint` is silent: with queued
    /// work but no hint the clock jumps here instead of crawling in 1 ms
    /// hops. Advisory only — the pump re-polls at the returned time, so a
    /// loose bound costs extra polls, never correctness. None = no queued
    /// work, or the policy does not track deadlines.
    fn earliest_deadline(&self) -> Option<Micros> {
        None
    }

    /// Number of queued (not yet executing) requests.
    fn pending(&self) -> usize;

    /// Number of queued requests for one model (per-model load accounting
    /// for the routers).
    fn pending_for(&self, model: ModelId) -> usize;

    /// Estimated milliseconds to drain `model`'s currently queued work on
    /// this replica under the policy's own latency belief, including any
    /// cold-start surcharge the policy tracks (admission control reads
    /// this on every arrival — it must be cheap and allocation-free).
    /// `&mut` because distribution-backed policies answer from an
    /// entry-cached estimator. Default: queued count at a 10 ms/request
    /// placeholder, the same cold-start fallback the estimator uses.
    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        self.pending_for(model) as f64 * 10.0
    }

    /// The prediction made for the batch most recently returned by
    /// `next_batch` (telemetry; read by the serving core right after
    /// formation). None = this policy does not predict. Storing it must
    /// not change scheduling decisions — the golden dispatch snapshots
    /// pin that.
    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        None
    }
}

/// Mutable borrows are schedulers too, so the clock-generic serving core
/// (`serve::ServingLoop`) can drive a scheduler it does not own — e.g. the
/// single-worker `sim::engine::run` compatibility shim.
impl<S: Scheduler + ?Sized> Scheduler for &mut S {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        (**self).seed_app_profile(model, app, hist, weight)
    }
    fn install_model(&mut self, model: ModelId, cold_start_ms: f64, now: Micros) {
        (**self).install_model(model, cold_start_ms, now)
    }
    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        (**self).evict_model(model)
    }
    fn reap(&mut self, now: Micros) {
        (**self).reap(now)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn earliest_deadline(&self) -> Option<Micros> {
        (**self).earliest_deadline()
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
    fn pending_for(&self, model: ModelId) -> usize {
        (**self).pending_for(model)
    }
    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        (**self).backlog_estimate(model)
    }
    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        (**self).last_batch_prediction()
    }
}

impl Scheduler for Box<dyn Scheduler> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        (**self).seed_app_profile(model, app, hist, weight)
    }
    fn install_model(&mut self, model: ModelId, cold_start_ms: f64, now: Micros) {
        (**self).install_model(model, cold_start_ms, now)
    }
    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        (**self).evict_model(model)
    }
    fn reap(&mut self, now: Micros) {
        (**self).reap(now)
    }
    fn on_arrival(&mut self, req: Request, now: Micros) {
        (**self).on_arrival(req, now)
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        (**self).next_batch(now)
    }
    fn on_batch_complete(&mut self, batch: &[Request], batch_ms: f64, now: Micros) {
        (**self).on_batch_complete(batch, batch_ms, now)
    }
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        (**self).drain_dropped()
    }
    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        (**self).wake_hint(now)
    }
    fn earliest_deadline(&self) -> Option<Micros> {
        (**self).earliest_deadline()
    }
    fn pending(&self) -> usize {
        (**self).pending()
    }
    fn pending_for(&self, model: ModelId) -> usize {
        (**self).pending_for(model)
    }
    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        (**self).backlog_estimate(model)
    }
    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        (**self).last_batch_prediction()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: u32, slo_us: Micros) -> Request {
        Request::new(id, AppId(0), 0, slo_us, 5.0).with_model(ModelId(model))
    }

    #[test]
    fn fifo_queues_preserve_global_arrival_order() {
        let mut q = FifoQueues::new();
        for i in 0..6 {
            q.push(req(i, (i % 2) as u32, 1_000_000));
        }
        assert_eq!(q.len(), 6);
        assert_eq!(q.front().unwrap().id.0, 0);
        // Model-pure fill in arrival order, other lanes untouched.
        let batch = q.drain_model(ModelId(0), 2);
        assert_eq!(batch.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(q.pending_for(ModelId(0)), 1);
        assert_eq!(q.pending_for(ModelId(1)), 3);
        // Global head is now the earliest remaining arrival (id 1).
        assert_eq!(q.front().unwrap().id.0, 1);
        // Popping the global head interleaves lanes back into one FIFO.
        let order: Vec<u64> = std::iter::from_fn(|| q.pop_front()).map(|r| r.id.0).collect();
        assert_eq!(order, vec![1, 3, 4, 5]);
        assert!(q.is_empty());
    }

    #[test]
    fn fifo_front_matching_filters_lanes() {
        let mut q = FifoQueues::new();
        for i in 0..6 {
            q.push(req(i, (i % 3) as u32, 1_000_000));
        }
        // Unrestricted: the global head.
        assert_eq!(q.front_matching(|_| true).unwrap().id.0, 0);
        // Restricted to model 2: earliest arrival in that lane (id 2).
        assert_eq!(q.front_matching(|m| m == ModelId(2)).unwrap().id.0, 2);
        // Earliest across a subset of lanes.
        assert_eq!(
            q.front_matching(|m| m == ModelId(1) || m == ModelId(2))
                .unwrap()
                .id
                .0,
            1
        );
        assert!(q.front_matching(|m| m == ModelId(9)).is_none());
    }

    #[test]
    fn fifo_drain_caps_at_lane_length() {
        let mut q = FifoQueues::new();
        for i in 0..3 {
            q.push(req(i, 0, 1_000));
        }
        let batch = q.drain_model(ModelId(0), 10);
        assert_eq!(batch.len(), 3);
        assert!(q.drain_model(ModelId(7), 4).is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn edf_queues_order_by_deadline_then_id() {
        let mut q = EdfQueues::new();
        for i in 0..6u64 {
            q.push(req(i, (i % 2) as u32, 1_000 * (i + 1)));
        }
        // Same-deadline tie-break by id.
        q.push(req(9, 1, 1_000));
        assert_eq!(q.len(), 7);
        // Global head: deadline 1000, smaller id wins.
        assert_eq!(q.peek().unwrap().id.0, 0);
        assert_eq!(q.min_deadline(), Some(req(0, 0, 1_000).deadline));
        // Model-1 fill in deadline order: id 9 (d=1000) before 1 (d=2000).
        let batch = q.drain_model(ModelId(1), 2);
        assert_eq!(batch.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![9, 1]);
        assert_eq!(q.pending_for(ModelId(1)), 2);
        // Other lane untouched; global pops stay in deadline order.
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop_head()).map(|r| r.id.0).collect();
        assert_eq!(rest, vec![0, 2, 3, 4, 5]);
        assert!(q.is_empty());
        assert_eq!(q.min_deadline(), None);
    }

    #[test]
    fn fifo_lane_lifecycle_installs_and_drains() {
        let mut q = FifoQueues::new();
        q.ensure_lane(ModelId(3));
        assert_eq!(q.pending_for(ModelId(3)), 0);
        assert!(q.is_empty(), "ensure_lane creates empty state only");
        for i in 0..5 {
            q.push(req(i, (i % 2) as u32, 1_000_000));
        }
        // Evicting model 0 drains its lane in arrival order; model 1 and
        // the global sequence numbering are untouched.
        let drained = q.remove_lane(ModelId(0));
        assert_eq!(
            drained.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        assert_eq!(q.len(), 2);
        assert_eq!(q.pending_for(ModelId(0)), 0);
        assert_eq!(q.pending_for(ModelId(1)), 2);
        assert!(q.remove_lane(ModelId(9)).is_empty(), "absent lane is a no-op");
        // Reinstall and refill: the lane works again.
        q.ensure_lane(ModelId(0));
        q.push(req(7, 0, 1_000_000));
        assert_eq!(q.pending_for(ModelId(0)), 1);
    }

    #[test]
    fn edf_lane_lifecycle_drains_in_deadline_order() {
        let mut q = EdfQueues::new();
        q.ensure_lane(ModelId(0));
        q.push(req(0, 0, 9_000));
        q.push(req(1, 0, 1_000));
        q.push(req(2, 1, 4_000));
        let drained = q.remove_lane(ModelId(0));
        assert_eq!(
            drained.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            vec![1, 0],
            "deadline order"
        );
        assert_eq!(q.len(), 1);
        assert_eq!(q.min_deadline(), Some(req(2, 1, 4_000).deadline));
        assert!(q.remove_lane(ModelId(5)).is_empty());
    }

    #[test]
    fn edf_pending_counts_track_lanes() {
        let mut q = EdfQueues::new();
        assert_eq!(q.pending_for(ModelId(0)), 0);
        q.push(req(0, 0, 5_000));
        q.push(req(1, 0, 4_000));
        q.push(req(2, 3, 1_000));
        assert_eq!(q.pending_for(ModelId(0)), 2);
        assert_eq!(q.pending_for(ModelId(3)), 1);
        assert_eq!(q.peek().unwrap().id.0, 2, "model-3 deadline is earliest");
        q.pop_head();
        assert_eq!(q.pending_for(ModelId(3)), 0);
    }
}
